//! The six determinism/concurrency rules of `picbnn-lint`.
//!
//! Each rule is a linear scan over the token stream from
//! [`super::lexer`]; none of them parse Rust.  The only stateful one is
//! `lock-discipline`, which runs a conservative intra-function guard
//! tracker (documented on [`check_lock_discipline`]).  Rule scopes are
//! path-based: `rust/src/**` is production code, `server/`+`accel/`
//! under it are the hot paths, and a small allowlist covers the three
//! sanctioned wall-clock seams.
//!
//! DETERMINISM.md enumerates the invariant behind every rule and the
//! suppression pragma syntax.

use super::lexer::{Lexed, Tok, TokKind};

/// Every suppressible rule, in reporting order.  (`pragma`, the
/// hygiene meta-rule, is deliberately absent: you cannot allow your way
/// out of a malformed allow.)
pub const RULE_NAMES: &[&str] = &[
    "clock-seam",
    "seeded-rng",
    "no-hash-iter",
    "lock-discipline",
    "condvar-predicate",
    "no-panic-markers",
];

/// One rule violation at a source location.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// Output of running every rule over one file.
#[derive(Debug, Default)]
pub struct RuleOutput {
    pub findings: Vec<Finding>,
    /// `.unwrap()`s classified as acceptable poison-propagation idiom
    /// (lock/wait results) in hot-path scope — reported for visibility.
    pub poison_unwraps: usize,
}

/// Sanctioned raw-time seams: the `Clock` implementation itself,
/// `util::Timer` (which benches and the CLI wrap), and `benchkit`'s
/// wall-clock measurement loops.
fn clock_allowlisted(relpath: &str) -> bool {
    relpath == "rust/src/server/clock.rs"
        || relpath == "rust/src/util/mod.rs"
        || relpath.starts_with("rust/src/benchkit/")
}

fn is_src(relpath: &str) -> bool {
    relpath.starts_with("rust/src/")
}

/// Hot-path scope for the unwrap classification: the serving engine and
/// the accelerator pool, where a stray panic takes down a worker thread
/// mid-batch.
fn is_hot_path(relpath: &str) -> bool {
    is_src(relpath) && (relpath.contains("/server/") || relpath.contains("/accel/"))
}

/// Run all six rules over one lexed file.
pub fn run(relpath: &str, lexed: &Lexed) -> RuleOutput {
    let mut out = RuleOutput::default();
    if is_src(relpath) && !clock_allowlisted(relpath) {
        check_clock_seam(relpath, lexed, &mut out);
    }
    check_seeded_rng(relpath, lexed, &mut out);
    if is_src(relpath) {
        check_hash_iter(relpath, lexed, &mut out);
        check_panic_markers(relpath, lexed, &mut out);
    }
    check_condvar_predicate(relpath, lexed, &mut out);
    check_lock_discipline(relpath, lexed, &mut out);
    out.findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// `clock-seam`: no `Instant::now()` / `SystemTime::now()` outside the
/// allowlisted seams.  Raw time reads anywhere else make replay under
/// the simulated `Clock` diverge from wall-clock runs.
fn check_clock_seam(relpath: &str, lexed: &Lexed, out: &mut RuleOutput) {
    let t = &lexed.toks;
    for i in 0..t.len().saturating_sub(4) {
        let src_ty = if t[i].is_ident("Instant") {
            "Instant"
        } else if t[i].is_ident("SystemTime") {
            "SystemTime"
        } else {
            continue;
        };
        if t[i + 1].is_punct(b':')
            && t[i + 2].is_punct(b':')
            && t[i + 3].is_ident("now")
            && t[i + 4].is_punct(b'(')
        {
            out.findings.push(Finding {
                rule: "clock-seam",
                file: relpath.to_string(),
                line: t[i].line,
                message: format!(
                    "raw `{src_ty}::now()` outside the Clock seam — take time through \
                     `server::Clock` (or `util::Timer` in benches) so simulated-time \
                     replay stays exact"
                ),
            });
        }
    }
}

/// `seeded-rng`: RNG state may only come from `util::rng` constructors
/// with an explicit seed.  Ambient-entropy constructors make every
/// "deterministic for any thread count / batch shape" property test a
/// lie.
fn check_seeded_rng(relpath: &str, lexed: &Lexed, out: &mut RuleOutput) {
    const BANNED: &[&str] = &[
        "thread_rng",
        "from_entropy",
        "OsRng",
        "getrandom",
        "RandomState",
        "DefaultHasher",
        "StdRng",
        "SmallRng",
    ];
    for tok in &lexed.toks {
        if tok.kind == TokKind::Ident && BANNED.contains(&tok.text.as_str()) {
            out.findings.push(Finding {
                rule: "seeded-rng",
                file: relpath.to_string(),
                line: tok.line,
                message: format!(
                    "`{}` draws ambient entropy — construct RNGs through `util::rng` \
                     with an explicit seed so runs replay bit-exact",
                    tok.text
                ),
            });
        }
    }
}

/// `no-hash-iter`: `HashMap`/`HashSet` are banned in `src/` outright —
/// `RandomState` iteration order varies per process, which breaks
/// replica-count-invariant planning and seed replay.  Use `BTreeMap`
/// or a sorted `Vec`.
fn check_hash_iter(relpath: &str, lexed: &Lexed, out: &mut RuleOutput) {
    for tok in &lexed.toks {
        if tok.is_ident("HashMap") || tok.is_ident("HashSet") {
            out.findings.push(Finding {
                rule: "no-hash-iter",
                file: relpath.to_string(),
                line: tok.line,
                message: format!(
                    "`{}` in production code — RandomState iteration order breaks \
                     deterministic replay; use `BTreeMap`/`BTreeSet` or a sorted Vec",
                    tok.text
                ),
            });
        }
    }
}

/// `condvar-predicate`: bare `.wait(…)` / `.wait_timeout(…)` are banned
/// everywhere — spurious wakeups make them return without the guarded
/// condition holding.  Use `wait_while` / `wait_timeout_while`.
fn check_condvar_predicate(relpath: &str, lexed: &Lexed, out: &mut RuleOutput) {
    let t = &lexed.toks;
    for i in 0..t.len().saturating_sub(2) {
        if !t[i].is_punct(b'.') || !t[i + 2].is_punct(b'(') {
            continue;
        }
        let name = if t[i + 1].is_ident("wait") {
            "wait"
        } else if t[i + 1].is_ident("wait_timeout") {
            "wait_timeout"
        } else {
            continue;
        };
        out.findings.push(Finding {
            rule: "condvar-predicate",
            file: relpath.to_string(),
            line: t[i + 1].line,
            message: format!(
                "bare `.{name}(…)` is vulnerable to spurious wakeups — use the \
                 predicate form (`wait_while` / `wait_timeout_while`)"
            ),
        });
    }
}

/// `no-panic-markers`: `todo!` / `unimplemented!` / `dbg!` banned in
/// `src/` (inline test modules included — a `dbg!` in a test pollutes
/// CI logs and a `todo!` is a landmine either way).
fn check_panic_markers(relpath: &str, lexed: &Lexed, out: &mut RuleOutput) {
    let t = &lexed.toks;
    for i in 0..t.len().saturating_sub(1) {
        if t[i].kind != TokKind::Ident || !t[i + 1].is_punct(b'!') {
            continue;
        }
        let name = t[i].text.as_str();
        if name == "todo" || name == "unimplemented" || name == "dbg" {
            out.findings.push(Finding {
                rule: "no-panic-markers",
                file: relpath.to_string(),
                line: t[i].line,
                message: format!("`{name}!` must not ship in src/"),
            });
        }
    }
}

/// A live guard in the `lock-discipline` tracker.
struct Guard {
    /// Binding name (`let g = ….lock().unwrap();`); `None` for
    /// temporaries.
    name: Option<String>,
    line: u32,
    /// Brace depth at acquisition — leaving this depth releases it.
    depth: i32,
    kind: &'static str,
    bound: bool,
}

/// `lock-discipline`, two checks in one pass over each file:
///
/// 1. **No nested blocking acquisitions.**  A conservative guard
///    tracker flags any `.lock()` / `.write()` (empty-arg forms only —
///    `.write(buf)` is I/O, `.try_lock()` cannot deadlock as the inner
///    acquisition) taken while another tracked guard is still live.
///    Guard lifetime heuristic, deliberately simple:
///    * `let g = <chain ending .unwrap()/.expect(…)>;` binds a guard
///      that lives to the end of its block or to `drop(g)`;
///    * any other acquisition is a temporary that dies at the next `;`
///      at its own brace depth (so a `match x.lock().unwrap() { … }`
///      scrutinee guard correctly lives through the arms);
///    * leaving the enclosing block releases everything acquired in it.
///    The tracker is intra-function by construction: a function body's
///    closing brace releases its guards, so cross-function ordering is
///    out of scope (and stays the job of the TSan CI lane).
///
/// 2. **Unwrap classification in hot paths** (`server/`/`accel/` src,
///    `#[cfg(test)]` modules exempt): `.unwrap()` directly on the
///    result of a lock-family call (`lock`/`read`/`write`/`get_mut`/
///    `into_inner`/`try_lock`/`wait*`) is the sanctioned
///    poison-propagation idiom — a poisoned mutex means a sibling
///    thread already panicked, and unwrapping spreads the abort instead
///    of computing with torn state.  Any *other* `.unwrap()` is a
///    finding: replace it with `.expect("<invariant>")` or real
///    handling.
fn check_lock_discipline(relpath: &str, lexed: &Lexed, out: &mut RuleOutput) {
    let t = &lexed.toks;
    let mut depth = 0i32;
    let mut guards: Vec<Guard> = Vec::new();
    // `(name, deref)`: the current statement is `let [mut] name = …`;
    // `deref` records a `*` right after the `=`, which means the lock
    // chain's value is copied out and the guard is a temporary
    // (`let x = *self.a.lock().unwrap();`)
    let mut pending_let: Option<(String, bool)> = None;
    // poison-unwrap channel: callee name of each currently-open paren
    // group, plus the callee of the most recently closed one
    let mut paren_callees: Vec<Option<String>> = Vec::new();
    let mut last_closed: Option<String> = None;
    const POISON: &[&str] = &[
        "lock",
        "read",
        "write",
        "get_mut",
        "into_inner",
        "try_lock",
        "wait",
        "wait_while",
        "wait_timeout",
        "wait_timeout_while",
    ];
    let unwrap_scope = is_hot_path(relpath);

    let mut i = 0usize;
    while i < t.len() {
        let tok = &t[i];
        match (tok.kind, tok.punct) {
            (TokKind::Punct, b'{') => depth += 1,
            (TokKind::Punct, b'}') => {
                depth -= 1;
                guards.retain(|g| g.depth <= depth);
            }
            (TokKind::Punct, b';') => {
                guards.retain(|g| g.bound || g.depth < depth);
                pending_let = None;
            }
            (TokKind::Punct, b'(') => {
                let callee = if i > 0 && t[i - 1].kind == TokKind::Ident {
                    Some(t[i - 1].text.clone())
                } else {
                    None
                };
                paren_callees.push(callee);
            }
            (TokKind::Punct, b')') => {
                last_closed = paren_callees.pop().flatten();
            }
            (TokKind::Ident, _) if tok.text == "let" => {
                // `let [mut] name` followed by `=` or `:` arms the
                // bound-guard classification for this statement
                let mut j = i + 1;
                if j < t.len() && t[j].is_ident("mut") {
                    j += 1;
                }
                if j + 1 < t.len()
                    && t[j].kind == TokKind::Ident
                    && (t[j + 1].is_punct(b'=') || t[j + 1].is_punct(b':'))
                {
                    let deref = t[j + 1].is_punct(b'=')
                        && j + 2 < t.len()
                        && t[j + 2].is_punct(b'*');
                    pending_let = Some((t[j].text.clone(), deref));
                }
            }
            (TokKind::Ident, _) if tok.text == "drop" => {
                // `drop(name)` releases the bound guard `name` early
                if i + 3 < t.len()
                    && t[i + 1].is_punct(b'(')
                    && t[i + 2].kind == TokKind::Ident
                    && t[i + 3].is_punct(b')')
                {
                    let name = &t[i + 2].text;
                    guards.retain(|g| g.name.as_deref() != Some(name.as_str()));
                }
            }
            (TokKind::Punct, b'.') if i + 3 < t.len() => {
                // `.unwrap()` — classify before the acquisition check so
                // the chain scan below can't skip past it
                if unwrap_scope
                    && t[i + 1].is_ident("unwrap")
                    && t[i + 2].is_punct(b'(')
                    && t[i + 3].is_punct(b')')
                    && !lexed.in_test_span(t[i + 1].line)
                {
                    let on_poison_result = i > 0
                        && t[i - 1].is_punct(b')')
                        && last_closed
                            .as_deref()
                            .is_some_and(|c| POISON.contains(&c));
                    if on_poison_result {
                        out.poison_unwraps += 1;
                    } else {
                        out.findings.push(Finding {
                            rule: "lock-discipline",
                            file: relpath.to_string(),
                            line: t[i + 1].line,
                            message: "non-poison `.unwrap()` in a hot path — use \
                                      `.expect(\"<invariant>\")` or handle the failure \
                                      (a bare unwrap here aborts a worker mid-batch)"
                                .to_string(),
                        });
                    }
                }
                // blocking acquisition: `.lock()` / `.write()` with
                // empty parens
                let kind = if t[i + 1].is_ident("lock") {
                    "lock"
                } else if t[i + 1].is_ident("write") {
                    "write"
                } else {
                    ""
                };
                if !kind.is_empty() && t[i + 2].is_punct(b'(') && t[i + 3].is_punct(b')') {
                    let line = t[i + 1].line;
                    if let Some(outer) = guards.first() {
                        let held = match &outer.name {
                            Some(n) => format!("guard `{n}`"),
                            None => "a temporary guard".to_string(),
                        };
                        out.findings.push(Finding {
                            rule: "lock-discipline",
                            file: relpath.to_string(),
                            line,
                            message: format!(
                                "nested blocking acquisition: `.{kind}()` while {held} \
                                 (line {}, `.{}()`) is still held — release the outer \
                                 guard first or restructure to a single acquisition",
                                outer.line, outer.kind
                            ),
                        });
                    }
                    // bound iff the statement is `let name = <chain
                    // ending .unwrap()/.expect(…)>;`
                    let mut last_method = kind.to_string();
                    let mut j = i + 4;
                    while j + 2 < t.len()
                        && t[j].is_punct(b'.')
                        && t[j + 1].kind == TokKind::Ident
                        && t[j + 2].is_punct(b'(')
                    {
                        last_method = t[j + 1].text.clone();
                        j = skip_paren_group(t, j + 2);
                    }
                    let bound = pending_let.as_ref().is_some_and(|(_, deref)| !deref)
                        && (last_method == "unwrap" || last_method == "expect")
                        && j < t.len()
                        && t[j].is_punct(b';');
                    guards.push(Guard {
                        name: if bound {
                            pending_let.take().map(|(n, _)| n)
                        } else {
                            None
                        },
                        line,
                        depth,
                        kind,
                        bound,
                    });
                }
            }
            _ => {}
        }
        i += 1;
    }
}

/// Index just past the paren group opening at `open` (which must be a
/// `(` token).  Unbalanced input returns the end of the stream.
fn skip_paren_group(t: &[Tok], open: usize) -> usize {
    let mut depth = 0i32;
    let mut j = open;
    while j < t.len() {
        if t[j].is_punct(b'(') {
            depth += 1;
        } else if t[j].is_punct(b')') {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    j
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn findings(relpath: &str, src: &str) -> Vec<(String, u32)> {
        run(relpath, &lex(src))
            .findings
            .iter()
            .map(|f| (f.rule.to_string(), f.line))
            .collect()
    }

    #[test]
    fn bound_guard_then_second_lock_flags() {
        let src = "fn f(&self) {\n    let st = self.placement.write().unwrap();\n    let m = self.stats.lock().unwrap();\n}\n";
        let got = findings("rust/src/accel/x.rs", src);
        assert_eq!(got, vec![("lock-discipline".to_string(), 3)]);
    }

    #[test]
    fn sequential_temporaries_do_not_flag() {
        let src = "fn f(&self) {\n    self.a.lock().unwrap().push(1);\n    self.b.lock().unwrap().push(2);\n}\n";
        assert!(findings("rust/src/accel/x.rs", src).is_empty());
    }

    #[test]
    fn drop_releases_the_bound_guard() {
        let src = "fn f(&self) {\n    let st = self.a.lock().unwrap();\n    drop(st);\n    let q = self.b.lock().unwrap();\n}\n";
        assert!(findings("rust/src/accel/x.rs", src).is_empty());
    }

    #[test]
    fn deref_copy_guard_is_a_temporary() {
        let src = "fn f(&self) -> (u64, u64) {\n    let x = *self.a.lock().unwrap();\n    let y = *self.b.lock().unwrap();\n    (x, y)\n}\n";
        assert!(findings("rust/src/accel/x.rs", src).is_empty());
    }

    #[test]
    fn block_exit_releases_guards() {
        let src = "fn f(&self) {\n    {\n        let st = self.a.lock().unwrap();\n    }\n    let q = self.b.lock().unwrap();\n}\n";
        assert!(findings("rust/src/accel/x.rs", src).is_empty());
    }

    #[test]
    fn match_scrutinee_guard_lives_through_arms() {
        let src = "fn f(&self) -> u32 {\n    let advance = match &*self.service.lock().unwrap() {\n        Some(v) => self.other.lock().unwrap().len() as u32,\n        None => 0,\n    };\n    advance\n}\n";
        let got = findings("rust/src/server/x.rs", src);
        assert_eq!(got, vec![("lock-discipline".to_string(), 3)]);
    }

    #[test]
    fn try_lock_is_not_a_tracked_acquisition() {
        let src = "fn f(&self) {\n    let Ok(g) = self.m.try_lock() else { return };\n    let st = self.a.lock().unwrap();\n}\n";
        assert!(findings("rust/src/server/x.rs", src).is_empty());
    }

    #[test]
    fn io_write_with_args_is_not_an_acquisition() {
        let src = "fn f(&self, dev: &mut D) {\n    dev.write(addr, val);\n    let st = self.a.lock().unwrap();\n}\n";
        assert!(findings("rust/src/accel/x.rs", src).is_empty());
    }

    #[test]
    fn poison_unwrap_is_counted_not_flagged() {
        let src = "fn f(&self) {\n    let st = self.a.lock().unwrap();\n    let r = self.b.read().unwrap();\n}\n";
        // note: .read() is a shared acquisition, not tracked for nesting
        let out = run("rust/src/server/x.rs", &lex(src));
        assert!(out.findings.is_empty());
        assert_eq!(out.poison_unwraps, 2);
    }

    #[test]
    fn real_unwrap_in_hot_path_flags_but_tests_exempt() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n#[cfg(test)]\nmod tests {\n    fn t(x: Option<u32>) -> u32 {\n        x.unwrap()\n    }\n}\n";
        let got = findings("rust/src/accel/x.rs", src);
        assert_eq!(got, vec![("lock-discipline".to_string(), 2)]);
    }

    #[test]
    fn unwrap_outside_hot_path_is_ignored() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert!(findings("rust/src/bnn/x.rs", src).is_empty());
    }

    #[test]
    fn multiline_poison_chain_is_poison() {
        let src = "fn f(&self) {\n    let v = self\n        .stats\n        .lock()\n        .unwrap()\n        .total;\n}\n";
        let out = run("rust/src/server/x.rs", &lex(src));
        assert!(out.findings.is_empty());
        assert_eq!(out.poison_unwraps, 1);
    }

    #[test]
    fn clock_seam_fires_off_allowlist_only() {
        let src = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(
            findings("rust/src/accel/x.rs", src),
            vec![("clock-seam".to_string(), 1)]
        );
        assert!(findings("rust/src/server/clock.rs", src).is_empty());
        assert!(findings("rust/src/benchkit/mod.rs", src).is_empty());
        // tests/benches take time however they like
        assert!(findings("rust/tests/x.rs", src).is_empty());
    }

    #[test]
    fn condvar_and_rng_and_markers_fire() {
        let src = "fn f(&self) {\n    let g = self.cv.wait(g);\n    let h = RandomState::new();\n    todo!()\n}\n";
        let got = findings("rust/src/server/x.rs", src);
        let rules: Vec<&str> = got.iter().map(|(r, _)| r.as_str()).collect();
        // sorted by line: wait (2), RandomState (3), todo! (4)
        assert_eq!(
            rules,
            vec!["condvar-predicate", "seeded-rng", "no-panic-markers"]
        );
    }

    #[test]
    fn wait_timeout_while_is_fine() {
        let src = "fn f(&self) {\n    let (g, _) = self.cv.wait_timeout_while(g, d, |s| s.idle).unwrap();\n}\n";
        assert!(findings("rust/src/server/x.rs", src).is_empty());
    }

    #[test]
    fn hash_containers_banned_in_src_only() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(
            findings("rust/src/util/x.rs", src),
            vec![("no-hash-iter".to_string(), 1)]
        );
        assert!(findings("rust/tests/x.rs", src).is_empty());
    }
}
