// picbnn-lint fixture: clean under `no-hash-iter` — ordered container,
// deterministic iteration.
use std::collections::BTreeMap;

pub fn total(m: &BTreeMap<u32, u64>) -> u64 {
    m.values().sum()
}
