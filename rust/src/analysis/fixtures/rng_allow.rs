// picbnn-lint fixture: `seeded-rng` violation suppressed by a same-line
// pragma.
pub fn roll() -> u64 {
    let mut rng = rand::thread_rng(); // picbnn: allow(seeded-rng) — fixture shows same-line suppression
    rng.gen()
}
