// picbnn-lint fixture: `condvar-predicate` MUST fire — a bare
// `.wait(…)` is vulnerable to spurious wakeups.
use std::sync::{Condvar, Mutex};

pub struct Gate {
    lock: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    pub fn block(&self) {
        let guard = self.lock.lock().unwrap();
        let _unused = self.cv.wait(guard).unwrap();
    }
}
