// picbnn-lint fixture: `lock-discipline` (nested acquisition) MUST
// fire — a second blocking lock is taken while the bound write guard
// is still held.
use std::sync::{Mutex, RwLock};

pub struct S {
    placement: RwLock<u32>,
    stats: Mutex<u64>,
}

impl S {
    pub fn bump(&self) {
        let mut st = self.placement.write().unwrap();
        let mut stats = self.stats.lock().unwrap();
        *st += 1;
        *stats += 1;
    }
}
