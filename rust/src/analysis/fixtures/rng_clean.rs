// picbnn-lint fixture: clean under `seeded-rng` — the explicit-seed
// constructor from util::rng.
use crate::util::rng::Rng;

pub fn roll(seed: u64) -> u64 {
    let mut rng = Rng::new(seed, 0);
    rng.next_u64()
}
