// picbnn-lint fixture: clean under `no-panic-markers` — explicit
// errors instead of placeholder macros (and the marker names in this
// comment — todo!, dbg! — must not fire).
pub fn later() -> Result<u32, String> {
    Err("not implemented for this fixture".to_string())
}
