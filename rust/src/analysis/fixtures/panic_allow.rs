// picbnn-lint fixture: `no-panic-markers` suppressed by a line pragma.
pub fn probe(x: u32) -> u32 {
    // picbnn: allow(no-panic-markers) — fixture: temporary diagnostic kept on purpose
    dbg!(x)
}
