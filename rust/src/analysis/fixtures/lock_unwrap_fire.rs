// picbnn-lint fixture: `lock-discipline` (unwrap classification) MUST
// fire — a non-poison `.unwrap()` in hot-path scope.  The poison
// unwrap on the lock result below must NOT fire.
use std::sync::Mutex;

pub struct S {
    cache: Mutex<Vec<u32>>,
}

impl S {
    pub fn first(&self, xs: &[u32]) -> u32 {
        let held = self.cache.lock().unwrap();
        let _ = held.len();
        *xs.first().unwrap()
    }
}
