// picbnn-lint fixture: `condvar-predicate` suppressed by a line
// pragma.
use std::sync::{Condvar, Mutex};

pub struct Gate {
    lock: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    pub fn block(&self) {
        let guard = self.lock.lock().unwrap();
        // picbnn: allow(condvar-predicate) — fixture: caller re-checks the predicate in its own loop
        let _unused = self.cv.wait(guard).unwrap();
    }
}
