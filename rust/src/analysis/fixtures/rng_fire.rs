// picbnn-lint fixture: `seeded-rng` MUST fire — ambient-entropy RNG
// construction.
pub fn roll() -> u64 {
    let mut rng = rand::thread_rng();
    rng.gen()
}
