// picbnn-lint fixture: clean under `lock-discipline` — sequential
// temporaries, an early `drop`, and poison unwraps on lock results
// only.
use std::sync::Mutex;

pub struct S {
    a: Mutex<u64>,
    b: Mutex<u64>,
}

impl S {
    pub fn shuffle(&self) {
        let mut a = self.a.lock().unwrap();
        *a += 1;
        drop(a);
        let mut b = self.b.lock().unwrap();
        *b += 1;
    }

    pub fn totals(&self) -> (u64, u64) {
        let x = *self.a.lock().unwrap();
        let y = *self.b.lock().unwrap();
        (x, y)
    }
}
