// picbnn-lint fixture: `lock-discipline` nested acquisition suppressed
// by a line pragma (the leaf-ordering pattern macro_pool uses).
use std::sync::{Mutex, RwLock};

pub struct S {
    placement: RwLock<u32>,
    migration: Mutex<u64>,
}

impl S {
    pub fn step(&self) {
        let mut st = self.placement.write().unwrap();
        // picbnn: allow(lock-discipline) — fixture: leaf stats mutex, strict placement→leaf order
        let mut mig = self.migration.lock().unwrap();
        *st += 1;
        *mig += 1;
    }
}
