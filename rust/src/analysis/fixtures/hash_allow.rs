// picbnn-lint fixture: `no-hash-iter` suppressed file-wide (the
// justification pattern for a module that never iterates).
// picbnn: allow-file(no-hash-iter) — fixture: lookups only, never iterated
use std::collections::HashMap;

pub fn lookup(m: &HashMap<u32, u64>, k: u32) -> Option<u64> {
    m.get(&k).copied()
}
