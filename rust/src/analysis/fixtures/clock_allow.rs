// picbnn-lint fixture: `clock-seam` violation suppressed by a line
// pragma with a justification.
pub fn stamp() -> std::time::Instant {
    // picbnn: allow(clock-seam) — fixture demonstrates sanctioned wall timing
    std::time::Instant::now()
}
