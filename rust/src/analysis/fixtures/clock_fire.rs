// picbnn-lint fixture: `clock-seam` MUST fire twice here (Instant and
// SystemTime) when linted under a non-allowlisted src path.  This file
// is never compiled — it lives under fixtures/, which lint_tree skips.
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}

pub fn wall_secs() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}
