// picbnn-lint fixture: `no-panic-markers` MUST fire — a stray `todo!`
// in src/.
pub fn later() -> u32 {
    todo!()
}
