// picbnn-lint fixture: `no-hash-iter` MUST fire — HashMap in src/
// (RandomState iteration order breaks replay).
use std::collections::HashMap;

pub fn total(m: &HashMap<u32, u64>) -> u64 {
    m.values().sum()
}
