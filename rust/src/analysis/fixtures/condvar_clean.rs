// picbnn-lint fixture: clean under `condvar-predicate` — predicate
// forms re-check the condition across spurious wakeups.
use std::sync::{Condvar, Mutex};
use std::time::Duration;

pub struct Gate {
    lock: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    pub fn block(&self, d: Duration) -> bool {
        let guard = self.lock.lock().unwrap();
        let (open, _timeout) = self
            .cv
            .wait_timeout_while(guard, d, |open| !*open)
            .unwrap();
        *open
    }
}
