// picbnn-lint fixture: clean under `clock-seam` — time flows through
// the Clock seam, and mentions of Instant::now() in comments or
// "Instant::now()" in strings must not fire.
use crate::server::Clock;

pub fn stamp(clock: &Clock) -> u64 {
    let banner = "never call Instant::now() directly";
    let _ = banner;
    clock.now()
}
