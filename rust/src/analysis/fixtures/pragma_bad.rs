// picbnn-lint fixture: the `pragma` meta-rule MUST fire three times —
// a missing justification, an unknown rule name, and an unused allow —
// and the malformed allow must NOT suppress, so the clock-seam finding
// below survives as a fourth.
pub fn stamp() -> std::time::Instant {
    // picbnn: allow(clock-seam)
    std::time::Instant::now()
}

// picbnn: allow(not-a-rule) — rule name does not exist

// picbnn: allow(seeded-rng) — nothing in this file constructs an RNG
pub fn noop() {}
