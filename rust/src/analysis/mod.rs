//! `picbnn-lint`: static enforcement of the repo's determinism and
//! concurrency invariants.
//!
//! Every guarantee this codebase sells — batched ≡ sequential down to
//! RNG draw order, async ≡ sync bit-exactness, seed-replayable fault
//! drills — rests on conventions (the `Clock` seam, seeded RNG
//! construction, ordered containers, single-acquisition locking) that
//! the compiler cannot check.  This module turns those prose invariants
//! into machine-checked ones: a comment/string-aware lexer
//! ([`lexer`]), six token-level rules ([`rules`]), and a suppression
//! pragma grammar ([`pragma`]) feed a [`Report`] that the
//! `picbnn-lint` binary renders as human text or JSON (exit nonzero on
//! any unsuppressed finding) and that the `lint_clean` tier-1 test runs
//! over the real tree on every `cargo test`.
//!
//! The checker is deliberately token-level, not an AST: the rules are
//! chosen so that a conservative linear scan has no false negatives on
//! this codebase's idioms, and the few intentional violations carry
//! `// picbnn: allow(<rule>) — <why>` pragmas that double as
//! documentation.  DETERMINISM.md is the invariant catalogue.

pub mod lexer;
pub mod pragma;
pub mod rules;

#[cfg(test)]
mod fixture_tests;

pub use rules::{Finding, RULE_NAMES};

use crate::util::json::{obj, Json};
use std::path::{Path, PathBuf};

/// A finding that a pragma silenced, kept for the report (suppressions
/// are visible, never free).
#[derive(Clone, Debug)]
pub struct Suppressed {
    pub rule: String,
    pub file: String,
    pub line: u32,
    pub justification: String,
}

/// Aggregated lint result for one file or a whole tree.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    /// Unsuppressed findings — any entry here means a nonzero exit.
    pub findings: Vec<Finding>,
    pub suppressed: Vec<Suppressed>,
    /// Hot-path `.unwrap()`s classified as sanctioned poison
    /// propagation (informational).
    pub poison_unwraps: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    fn merge(&mut self, other: Report) {
        self.files_scanned += other.files_scanned;
        self.findings.extend(other.findings);
        self.suppressed.extend(other.suppressed);
        self.poison_unwraps += other.poison_unwraps;
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema_version", Json::Num(1.0)),
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            ("clean", Json::Bool(self.clean())),
            ("poison_unwraps", Json::Num(self.poison_unwraps as f64)),
            (
                "findings",
                Json::Arr(
                    self.findings
                        .iter()
                        .map(|f| {
                            obj(vec![
                                ("rule", Json::Str(f.rule.to_string())),
                                ("file", Json::Str(f.file.clone())),
                                ("line", Json::Num(f.line as f64)),
                                ("message", Json::Str(f.message.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "suppressed",
                Json::Arr(
                    self.suppressed
                        .iter()
                        .map(|s| {
                            obj(vec![
                                ("rule", Json::Str(s.rule.clone())),
                                ("file", Json::Str(s.file.clone())),
                                ("line", Json::Num(s.line as f64)),
                                ("justification", Json::Str(s.justification.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{} [{}] {}\n", f.file, f.line, f.rule, f.message));
        }
        for s in &self.suppressed {
            out.push_str(&format!(
                "{}:{} [{}] suppressed — {}\n",
                s.file, s.line, s.rule, s.justification
            ));
        }
        out.push_str(&format!(
            "picbnn-lint: {} file(s), {} finding(s), {} suppressed, {} poison unwrap(s)\n",
            self.files_scanned,
            self.findings.len(),
            self.suppressed.len(),
            self.poison_unwraps
        ));
        out
    }
}

/// Lint one source file.  `relpath` selects rule scopes (see
/// [`rules`]) and is what appears in findings; use forward slashes.
pub fn lint_source(relpath: &str, src: &str) -> Report {
    let lexed = lexer::lex(src);
    let mut report = Report {
        files_scanned: 1,
        ..Report::default()
    };

    let mut pragmas = Vec::new();
    for parsed in pragma::parse_all(&lexed.pragmas) {
        match parsed {
            pragma::Parsed::Ok(p) => pragmas.push(p),
            pragma::Parsed::Bad { line, message } => report.findings.push(Finding {
                rule: "pragma",
                file: relpath.to_string(),
                line,
                message,
            }),
        }
    }

    let ruled = rules::run(relpath, &lexed);
    report.poison_unwraps = ruled.poison_unwraps;
    let mut used = vec![false; pragmas.len()];
    for f in ruled.findings {
        match pragmas.iter().position(|p| p.covers(f.rule, f.line)) {
            Some(idx) => {
                used[idx] = true;
                report.suppressed.push(Suppressed {
                    rule: f.rule.to_string(),
                    file: f.file,
                    line: f.line,
                    justification: pragmas[idx].justification.clone(),
                });
            }
            None => report.findings.push(f),
        }
    }
    // a pragma that silences nothing is a dormant hole in the invariant
    // wall — stale allows must be cleaned up, so they fire themselves
    for (idx, p) in pragmas.iter().enumerate() {
        if !used[idx] {
            report.findings.push(Finding {
                rule: "pragma",
                file: relpath.to_string(),
                line: p.line,
                message: format!(
                    "unused pragma `allow{}({})` — it suppresses nothing; remove it",
                    if p.file_wide { "-file" } else { "" },
                    p.rule
                ),
            });
        }
    }
    report
        .findings
        .sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    report
}

/// The directories `lint_tree` walks, relative to the repo root.
const SCAN_ROOTS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

/// Lint the whole repo rooted at `root`.  Files under any `fixtures`
/// path component are skipped (they exist to violate rules on
/// purpose); everything else `.rs` under [`SCAN_ROOTS`] is scanned in
/// sorted path order so output is deterministic.
pub fn lint_tree(root: &Path) -> Result<Report, String> {
    let mut files: Vec<PathBuf> = Vec::new();
    for sub in SCAN_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    files.sort();
    let mut report = Report::default();
    for path in files {
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        report.merge(lint_source(&rel, &src));
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            if name == "fixtures" {
                continue;
            }
            collect_rs(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pragma_suppresses_and_is_recorded() {
        let src = "fn f() {\n    // picbnn: allow(clock-seam) — fixture exercises suppression\n    let t = Instant::now();\n}\n";
        let r = lint_source("rust/src/accel/x.rs", src);
        assert!(r.clean(), "findings: {:?}", r.findings);
        assert_eq!(r.suppressed.len(), 1);
        assert_eq!(r.suppressed[0].rule, "clock-seam");
        assert_eq!(
            r.suppressed[0].justification,
            "fixture exercises suppression"
        );
    }

    #[test]
    fn unused_pragma_fires_the_meta_rule() {
        let src = "// picbnn: allow(seeded-rng) — nothing here needs it\nfn f() {}\n";
        let r = lint_source("rust/src/accel/x.rs", src);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, "pragma");
        assert!(r.findings[0].message.contains("unused"));
    }

    #[test]
    fn malformed_pragma_fires_and_finding_survives() {
        let src = "fn f() {\n    // picbnn: allow(clock-seam)\n    let t = Instant::now();\n}\n";
        let r = lint_source("rust/src/accel/x.rs", src);
        let rules: Vec<&str> = r.findings.iter().map(|f| f.rule).collect();
        assert_eq!(rules, vec!["pragma", "clock-seam"]);
    }

    #[test]
    fn allow_file_covers_every_instance() {
        let src = "// picbnn: allow-file(no-hash-iter) — fixture\nuse std::collections::HashMap;\nfn f(m: &HashMap<u32, u32>) {}\n";
        let r = lint_source("rust/src/util/x.rs", src);
        assert!(r.clean());
        assert_eq!(r.suppressed.len(), 2);
    }

    #[test]
    fn json_roundtrips_and_reports_clean_flag() {
        let r = lint_source("rust/src/accel/x.rs", "fn f() { let t = Instant::now(); }\n");
        let j = r.to_json();
        let parsed = Json::parse(&j.to_string()).expect("lint JSON parses");
        assert_eq!(parsed.get("clean"), Some(&Json::Bool(false)));
        let findings = parsed.get("findings").and_then(|f| f.as_arr()).unwrap_or(&[]);
        assert_eq!(findings.len(), 1);
        assert_eq!(
            findings[0].get("rule").and_then(|r| r.as_str()),
            Some("clock-seam")
        );
    }
}
