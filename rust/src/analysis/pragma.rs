//! Suppression pragmas for `picbnn-lint`.
//!
//! Grammar (inside a `//` comment):
//!
//! ```text
//! // picbnn: allow(<rule>) — <justification>
//! // picbnn: allow-file(<rule>) — <justification>
//! ```
//!
//! A line pragma suppresses findings of `<rule>` on its own line or on
//! the line directly below (so it can sit above the offending
//! statement).  `allow-file` suppresses the rule for the whole file.
//! The justification is mandatory — an allow without a reason is itself
//! a finding — and the separator may be an em-dash, `--`, or `:` so the
//! pragma survives rustfmt and plain-ASCII editors alike.
//!
//! Pragma hygiene is enforced by the `pragma` meta-rule: malformed
//! pragmas, unknown rule names, missing justifications, and pragmas
//! that suppress nothing all fire (a stale allow is a dormant hole in
//! the invariant wall).

use super::lexer::RawPragma;
use super::rules::RULE_NAMES;

/// A parsed, well-formed suppression.
#[derive(Clone, Debug)]
pub struct Pragma {
    /// 1-based line the pragma comment sits on.
    pub line: u32,
    /// Rule it suppresses (one of [`RULE_NAMES`]).
    pub rule: String,
    /// `allow-file` form: applies to every line of the file.
    pub file_wide: bool,
    pub justification: String,
}

/// Outcome of parsing one raw pragma comment.
pub enum Parsed {
    Ok(Pragma),
    /// Malformed / unknown rule / missing justification — the message
    /// becomes a `pragma` finding at the comment's line.
    Bad { line: u32, message: String },
}

/// Parse every raw `picbnn:` comment the lexer collected.
pub fn parse_all(raw: &[RawPragma]) -> Vec<Parsed> {
    raw.iter().map(parse_one).collect()
}

fn parse_one(raw: &RawPragma) -> Parsed {
    let bad = |message: String| Parsed::Bad {
        line: raw.line,
        message,
    };
    let Some(after_marker) = raw.text.split("picbnn:").nth(1) else {
        return bad("pragma comment lost its `picbnn:` marker".to_string());
    };
    let body = after_marker.trim_start();
    let (file_wide, after_kw) = if let Some(rest) = body.strip_prefix("allow-file") {
        (true, rest)
    } else if let Some(rest) = body.strip_prefix("allow") {
        (false, rest)
    } else {
        return bad(format!(
            "unknown pragma `{}` — expected `allow(<rule>)` or `allow-file(<rule>)`",
            body.split_whitespace().next().unwrap_or("")
        ));
    };
    let after_kw = after_kw.trim_start();
    let Some(rest) = after_kw.strip_prefix('(') else {
        return bad("malformed pragma — expected `(` after `allow`".to_string());
    };
    let Some(close) = rest.find(')') else {
        return bad("malformed pragma — missing `)` after rule name".to_string());
    };
    let rule = rest[..close].trim();
    if !RULE_NAMES.contains(&rule) {
        return bad(format!(
            "unknown rule `{rule}` in pragma (known: {})",
            RULE_NAMES.join(", ")
        ));
    }
    let mut just = rest[close + 1..].trim();
    for sep in ["—", "--", "-", ":"] {
        if let Some(stripped) = just.strip_prefix(sep) {
            just = stripped.trim();
            break;
        }
    }
    if just.is_empty() {
        return bad(format!(
            "pragma `allow({rule})` has no justification — write `// picbnn: allow({rule}) — <why>`"
        ));
    }
    Parsed::Ok(Pragma {
        line: raw.line,
        rule: rule.to_string(),
        file_wide,
        justification: just.to_string(),
    })
}

impl Pragma {
    /// Whether this pragma covers a finding of `rule` at `line`.
    pub fn covers(&self, rule: &str, line: u32) -> bool {
        self.rule == rule && (self.file_wide || line == self.line || line == self.line + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(line: u32, text: &str) -> RawPragma {
        RawPragma {
            line,
            text: text.to_string(),
        }
    }

    #[test]
    fn well_formed_pragma_parses() {
        let p = parse_all(&[raw(10, " picbnn: allow(clock-seam) — bench wall timing")]);
        match &p[0] {
            Parsed::Ok(pr) => {
                assert_eq!(pr.rule, "clock-seam");
                assert!(!pr.file_wide);
                assert_eq!(pr.justification, "bench wall timing");
                assert!(pr.covers("clock-seam", 10));
                assert!(pr.covers("clock-seam", 11));
                assert!(!pr.covers("clock-seam", 12));
                assert!(!pr.covers("seeded-rng", 10));
            }
            Parsed::Bad { message, .. } => panic!("unexpected reject: {message}"),
        }
    }

    #[test]
    fn file_wide_covers_everything() {
        let p = parse_all(&[raw(1, " picbnn: allow-file(no-hash-iter) -- fixture")]);
        match &p[0] {
            Parsed::Ok(pr) => {
                assert!(pr.file_wide);
                assert!(pr.covers("no-hash-iter", 999));
            }
            Parsed::Bad { message, .. } => panic!("unexpected reject: {message}"),
        }
    }

    #[test]
    fn unknown_rule_and_missing_justification_reject() {
        let cases = [
            " picbnn: allow(not-a-rule) — x",
            " picbnn: allow(clock-seam)",
            " picbnn: allow(clock-seam) — ",
            " picbnn: deny(clock-seam) — x",
            " picbnn: allow clock-seam — x",
        ];
        for c in cases {
            match parse_one(&raw(1, c)) {
                Parsed::Bad { .. } => {}
                Parsed::Ok(_) => panic!("should have rejected: {c}"),
            }
        }
    }

    #[test]
    fn ascii_separators_accepted() {
        for c in [
            " picbnn: allow(seeded-rng) -- fixture rng",
            " picbnn: allow(seeded-rng): fixture rng",
            " picbnn: allow(seeded-rng) - fixture rng",
        ] {
            match parse_one(&raw(1, c)) {
                Parsed::Ok(pr) => assert_eq!(pr.justification, "fixture rng"),
                Parsed::Bad { message, .. } => panic!("rejected {c}: {message}"),
            }
        }
    }
}
