//! Fixture-based self-tests: every lint rule gets a firing fixture, a
//! clean fixture, and a pragma-suppressed fixture (ISSUE 9).  The
//! fixtures live under `fixtures/` — which `lint_tree` skips and cargo
//! never compiles — and are fed to [`super::lint_source`] under
//! synthetic repo paths so each lands in the scope its rule targets.

use super::{lint_source, Report};

fn fire(fixture: &str, as_path: &str, rule: &str) -> Report {
    let r = lint_source(as_path, fixture);
    assert!(
        !r.clean(),
        "fixture for `{rule}` at {as_path} should fire but was clean"
    );
    assert!(
        r.findings.iter().any(|f| f.rule == rule),
        "fixture at {as_path} fired {:?}, expected rule `{rule}`",
        r.findings
    );
    r
}

fn clean(fixture: &str, as_path: &str) -> Report {
    let r = lint_source(as_path, fixture);
    assert!(
        r.clean() && r.suppressed.is_empty(),
        "fixture at {as_path} should be clean with no suppressions: {:?}",
        r.findings
    );
    r
}

fn allow(fixture: &str, as_path: &str, rule: &str) -> Report {
    let r = lint_source(as_path, fixture);
    assert!(
        r.clean(),
        "pragma fixture at {as_path} should be clean: {:?}",
        r.findings
    );
    assert!(
        r.suppressed.iter().any(|s| s.rule == rule),
        "pragma fixture at {as_path} suppressed {:?}, expected `{rule}`",
        r.suppressed
    );
    r
}

#[test]
fn clock_seam_fixtures() {
    let r = fire(
        include_str!("fixtures/clock_fire.rs"),
        "rust/src/accel/fixture.rs",
        "clock-seam",
    );
    assert_eq!(r.findings.len(), 2, "Instant and SystemTime both fire");
    clean(
        include_str!("fixtures/clock_clean.rs"),
        "rust/src/accel/fixture.rs",
    );
    allow(
        include_str!("fixtures/clock_allow.rs"),
        "rust/src/accel/fixture.rs",
        "clock-seam",
    );
    // the same firing source is legal outside src/ (benches own their timing)
    clean(
        include_str!("fixtures/clock_fire.rs"),
        "rust/benches/fixture.rs",
    );
}

#[test]
fn seeded_rng_fixtures() {
    fire(
        include_str!("fixtures/rng_fire.rs"),
        "rust/src/server/fixture.rs",
        "seeded-rng",
    );
    clean(
        include_str!("fixtures/rng_clean.rs"),
        "rust/src/server/fixture.rs",
    );
    allow(
        include_str!("fixtures/rng_allow.rs"),
        "rust/src/server/fixture.rs",
        "seeded-rng",
    );
    // seeded-rng holds in tests/benches too (property tests must replay)
    fire(
        include_str!("fixtures/rng_fire.rs"),
        "rust/tests/fixture.rs",
        "seeded-rng",
    );
}

#[test]
fn hash_iter_fixtures() {
    fire(
        include_str!("fixtures/hash_fire.rs"),
        "rust/src/util/fixture.rs",
        "no-hash-iter",
    );
    clean(
        include_str!("fixtures/hash_clean.rs"),
        "rust/src/util/fixture.rs",
    );
    let r = allow(
        include_str!("fixtures/hash_allow.rs"),
        "rust/src/util/fixture.rs",
        "no-hash-iter",
    );
    assert_eq!(r.suppressed.len(), 2, "allow-file covers both mentions");
    // outside src/ the container choice is the test's business
    clean(include_str!("fixtures/hash_fire.rs"), "rust/tests/fixture.rs");
}

#[test]
fn lock_discipline_fixtures() {
    let r = fire(
        include_str!("fixtures/lock_fire.rs"),
        "rust/src/accel/fixture.rs",
        "lock-discipline",
    );
    assert_eq!(r.findings.len(), 1, "one nested acquisition");
    assert!(r.findings[0].message.contains("nested"));
    assert_eq!(r.poison_unwraps, 2, "both guard unwraps are poison idiom");

    let r = fire(
        include_str!("fixtures/lock_unwrap_fire.rs"),
        "rust/src/server/fixture.rs",
        "lock-discipline",
    );
    assert_eq!(r.findings.len(), 1, "only the non-poison unwrap fires");
    assert!(r.findings[0].message.contains("non-poison"));
    assert_eq!(r.poison_unwraps, 1);

    let r = clean(
        include_str!("fixtures/lock_clean.rs"),
        "rust/src/accel/fixture.rs",
    );
    assert_eq!(r.poison_unwraps, 4);

    allow(
        include_str!("fixtures/lock_allow.rs"),
        "rust/src/accel/fixture.rs",
        "lock-discipline",
    );
}

#[test]
fn condvar_fixtures() {
    fire(
        include_str!("fixtures/condvar_fire.rs"),
        "rust/src/server/fixture.rs",
        "condvar-predicate",
    );
    clean(
        include_str!("fixtures/condvar_clean.rs"),
        "rust/src/server/fixture.rs",
    );
    allow(
        include_str!("fixtures/condvar_allow.rs"),
        "rust/src/server/fixture.rs",
        "condvar-predicate",
    );
}

#[test]
fn panic_marker_fixtures() {
    fire(
        include_str!("fixtures/panic_fire.rs"),
        "rust/src/bnn/fixture.rs",
        "no-panic-markers",
    );
    clean(
        include_str!("fixtures/panic_clean.rs"),
        "rust/src/bnn/fixture.rs",
    );
    allow(
        include_str!("fixtures/panic_allow.rs"),
        "rust/src/bnn/fixture.rs",
        "no-panic-markers",
    );
}

#[test]
fn pragma_hygiene_fixture() {
    let r = lint_source(
        "rust/src/util/fixture.rs",
        include_str!("fixtures/pragma_bad.rs"),
    );
    let pragma_findings = r.findings.iter().filter(|f| f.rule == "pragma").count();
    assert_eq!(
        pragma_findings, 3,
        "missing justification + unknown rule + unused allow: {:?}",
        r.findings
    );
    assert!(
        r.findings
            .iter()
            .any(|f| f.rule == "clock-seam"),
        "a malformed allow must not suppress the underlying finding"
    );
    assert_eq!(r.findings.len(), 4);
    assert!(r.suppressed.is_empty());
}
