//! A comment/string-aware Rust lexer for `picbnn-lint` (no parsing
//! heroics — see `analysis` module docs).
//!
//! The token stream is deliberately coarse: identifiers carry their
//! text, punctuation carries its byte, and literals collapse to opaque
//! kinds.  That is exactly enough for the rule engine's pattern scans
//! (`Instant :: now (`, `. lock ( )`, brace-depth guard tracking) while
//! guaranteeing that tokens inside comments, doc comments, strings, raw
//! strings, and char literals can never fire a rule — the failure mode
//! that makes `grep`-based invariant checks unusable on this codebase
//! (module docs routinely *mention* `Instant::now()`).
//!
//! Two side channels ride along with the tokens:
//!
//! * **Pragmas** — line comments *beginning* with the `picbnn:` marker,
//!   i.e. `// picbnn: allow(<rule>) — <justification>` (or `allow-file`
//!   for a whole file).  Doc comments and comments that merely mention
//!   the marker (or a `picbnn::` crate path) are not candidates.  The
//!   lexer only extracts the raw comment; parsing and matching live in
//!   `analysis::pragma`.
//! * **`#[cfg(test)]` spans** — the line ranges of test modules, so
//!   rules scoped to production code (the hot-path unwrap scan) can skip
//!   test bodies without a second pass.

/// What a token is; only the distinctions the rules need.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (text in [`Tok::text`]).
    Ident,
    /// Single punctuation byte (in [`Tok::punct`]).
    Punct,
    /// Any number literal.
    Num,
    /// Any string literal (plain, raw, or byte).
    Str,
    /// A char literal.
    Char,
    /// A lifetime (`'a`, `'static`).
    Lifetime,
}

/// One lexed token.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    /// Identifier text (empty for non-identifiers — literal bodies are
    /// opaque to the rules by design).
    pub text: String,
    /// Punctuation byte (0 for non-punctuation).
    pub punct: u8,
    /// 1-based source line.
    pub line: u32,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_punct(&self, c: u8) -> bool {
        self.kind == TokKind::Punct && self.punct == c
    }
}

/// A `//` comment whose text contains the `picbnn:` marker, pre-split
/// from the token stream for the pragma parser.
#[derive(Clone, Debug)]
pub struct RawPragma {
    /// 1-based line the comment sits on.
    pub line: u32,
    /// Comment body after `//`, untrimmed.
    pub text: String,
}

/// Lexer output: tokens plus the pragma/test-span side channels.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub pragmas: Vec<RawPragma>,
    /// Inclusive 1-based line spans of `#[cfg(test)] mod … { … }` blocks.
    pub cfg_test_spans: Vec<(u32, u32)>,
}

impl Lexed {
    /// Whether `line` falls inside a `#[cfg(test)]` module.
    pub fn in_test_span(&self, line: u32) -> bool {
        self.cfg_test_spans
            .iter()
            .any(|&(lo, hi)| lo <= line && line <= hi)
    }
}

/// Tokenize `src`.  Unterminated constructs never panic: the lexer
/// simply runs to end of input (a lint must survive any file handed to
/// it, including its own fixtures).
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_ascii_whitespace() => i += 1,
            // line comment (also doc `///` and `//!`): pragma channel
            b'/' if b.get(i + 1) == Some(&b'/') => {
                let start = i + 2;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                let text = &src[start..i];
                // only comments that *begin* with the marker are pragma
                // candidates: doc comments (`///`, `//!`) and prose that
                // mentions `picbnn:` or a `picbnn::` path must not parse
                let trimmed = text.trim_start();
                if trimmed.starts_with("picbnn:") && !trimmed.starts_with("picbnn::") {
                    out.pragmas.push(RawPragma {
                        line,
                        text: text.to_string(),
                    });
                }
            }
            // block comment, nesting like Rust's
            b'/' if b.get(i + 1) == Some(&b'*') => {
                let mut depth = 1usize;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            // raw strings r"…" / r#"…"# (and br variants via the ident
            // path peeking below)
            b'r' if matches!(b.get(i + 1), Some(b'"') | Some(b'#')) && raw_str_at(b, i) => {
                i = consume_raw_str(b, i, &mut line, &mut out, line);
            }
            b'"' => {
                let start_line = line;
                i += 1;
                while i < b.len() {
                    match b[i] {
                        b'\\' => i += 2,
                        b'"' => {
                            i += 1;
                            break;
                        }
                        b'\n' => {
                            line += 1;
                            i += 1;
                        }
                        _ => i += 1,
                    }
                }
                out.toks.push(tok(TokKind::Str, start_line));
            }
            b'\'' => {
                // lifetime or char literal: a backslash or a close quote
                // two bytes on means char; otherwise lifetime
                let is_char = match (b.get(i + 1), b.get(i + 2)) {
                    (Some(b'\\'), _) => true,
                    (Some(_), Some(b'\'')) => true,
                    _ => false,
                };
                if is_char {
                    i += 1;
                    while i < b.len() {
                        match b[i] {
                            b'\\' => i += 2,
                            b'\'' => {
                                i += 1;
                                break;
                            }
                            _ => i += 1,
                        }
                    }
                    out.toks.push(tok(TokKind::Char, line));
                } else {
                    i += 1;
                    while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                        i += 1;
                    }
                    out.toks.push(tok(TokKind::Lifetime, line));
                }
            }
            c if c == b'_' || c.is_ascii_alphabetic() => {
                let start = i;
                while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                    i += 1;
                }
                // byte/raw-string prefixes: b"…", br"…", b'…'
                let word = &src[start..i];
                if (word == "b" || word == "br") && matches!(b.get(i), Some(b'"') | Some(b'#')) {
                    if word == "br" || b.get(i) == Some(&b'"') {
                        // rewind onto the quote machinery via raw/plain path
                        if b.get(i) == Some(&b'"') && word == "b" {
                            // plain byte string: reuse the string loop
                            let start_line = line;
                            i += 1;
                            while i < b.len() {
                                match b[i] {
                                    b'\\' => i += 2,
                                    b'"' => {
                                        i += 1;
                                        break;
                                    }
                                    b'\n' => {
                                        line += 1;
                                        i += 1;
                                    }
                                    _ => i += 1,
                                }
                            }
                            out.toks.push(tok(TokKind::Str, start_line));
                            continue;
                        }
                        i = consume_raw_str(b, i - word.len() + 1, &mut line, &mut out, line);
                        continue;
                    }
                }
                out.toks.push(Tok {
                    kind: TokKind::Ident,
                    text: word.to_string(),
                    punct: 0,
                    line,
                });
            }
            c if c.is_ascii_digit() => {
                while i < b.len()
                    && (b[i].is_ascii_alphanumeric() || b[i] == b'_' || b[i] == b'.')
                {
                    // `0..n` range: stop the number before `..`
                    if b[i] == b'.' && b.get(i + 1) == Some(&b'.') {
                        break;
                    }
                    i += 1;
                }
                out.toks.push(tok(TokKind::Num, line));
            }
            c => {
                out.toks.push(Tok {
                    kind: TokKind::Punct,
                    text: String::new(),
                    punct: c,
                    line,
                });
                i += 1;
            }
        }
    }
    find_cfg_test_spans(&mut out);
    out
}

fn tok(kind: TokKind, line: u32) -> Tok {
    Tok {
        kind,
        text: String::new(),
        punct: 0,
        line,
    }
}

/// Whether `r` at `i` begins a raw string (`r"`, `r#`), as opposed to an
/// identifier that merely starts with `r`.
fn raw_str_at(b: &[u8], i: usize) -> bool {
    let mut j = i + 1;
    while b.get(j) == Some(&b'#') {
        j += 1;
    }
    b.get(j) == Some(&b'"')
}

/// Consume a raw string starting at the `r` (or the `#`/`"` right after a
/// `br` prefix); returns the index past the closing delimiter.
fn consume_raw_str(
    b: &[u8],
    at: usize,
    line: &mut u32,
    out: &mut Lexed,
    start_line: u32,
) -> usize {
    let mut i = at;
    if b.get(i) == Some(&b'r') {
        i += 1;
    }
    let mut hashes = 0usize;
    while b.get(i) == Some(&b'#') {
        hashes += 1;
        i += 1;
    }
    if b.get(i) == Some(&b'"') {
        i += 1;
    }
    'scan: while i < b.len() {
        if b[i] == b'\n' {
            *line += 1;
            i += 1;
            continue;
        }
        if b[i] == b'"' {
            let mut k = 0usize;
            while k < hashes && b.get(i + 1 + k) == Some(&b'#') {
                k += 1;
            }
            if k == hashes {
                i += 1 + hashes;
                break 'scan;
            }
        }
        i += 1;
    }
    out.toks.push(tok(TokKind::Str, start_line));
    i
}

/// Record the line spans of `#[cfg(test)] mod … { … }` blocks (skipping
/// any further attributes between the cfg and the `mod`).
fn find_cfg_test_spans(lexed: &mut Lexed) {
    let t = &lexed.toks;
    let mut i = 0usize;
    while i + 6 < t.len() {
        let is_cfg_test = t[i].is_punct(b'#')
            && t[i + 1].is_punct(b'[')
            && t[i + 2].is_ident("cfg")
            && t[i + 3].is_punct(b'(')
            && t[i + 4].is_ident("test")
            && t[i + 5].is_punct(b')')
            && t[i + 6].is_punct(b']');
        if !is_cfg_test {
            i += 1;
            continue;
        }
        // skip trailing attributes, find `mod`
        let mut j = i + 7;
        while j < t.len() && t[j].is_punct(b'#') {
            // skip a balanced `[ … ]` attribute group
            let mut depth = 0i32;
            j += 1;
            while j < t.len() {
                if t[j].is_punct(b'[') {
                    depth += 1;
                } else if t[j].is_punct(b']') {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        if j < t.len() && (t[j].is_ident("mod") || t[j].is_ident("pub")) {
            // `pub mod` or `mod`
            if t[j].is_ident("pub") {
                j += 1;
            }
            if j < t.len() && t[j].is_ident("mod") {
                // find the opening brace, then its match
                while j < t.len() && !t[j].is_punct(b'{') {
                    j += 1;
                }
                if j < t.len() {
                    let start_line = t[i].line;
                    let mut depth = 0i32;
                    while j < t.len() {
                        if t[j].is_punct(b'{') {
                            depth += 1;
                        } else if t[j].is_punct(b'}') {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        j += 1;
                    }
                    let end_line = t[j.min(t.len() - 1)].line;
                    lexed.cfg_test_spans.push((start_line, end_line));
                    i = j;
                    continue;
                }
            }
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_hide_tokens() {
        let src = r##"
// Instant::now() in a comment
/* Instant::now() in a block /* nested */ comment */
let s = "Instant::now()";
let r = r#"Instant::now()"#;
let c = 'I';
let real = Instant::now();
"##;
        let lexed = lex(src);
        let hits: Vec<u32> = lexed
            .toks
            .iter()
            .filter(|t| t.is_ident("Instant"))
            .map(|t| t.line)
            .collect();
        assert_eq!(hits, vec![7], "only the real call site tokenizes");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let lexed = lex("fn f<'a>(x: &'a str) -> char { 'x' }");
        let lifetimes = lexed
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .count();
        let chars = lexed.toks.iter().filter(|t| t.kind == TokKind::Char).count();
        assert_eq!((lifetimes, chars), (2, 1));
    }

    #[test]
    fn escaped_char_literal_is_char() {
        let lexed = lex(r"let q = '\''; let n = '\n'; let l: &'static str;");
        let chars = lexed.toks.iter().filter(|t| t.kind == TokKind::Char).count();
        assert_eq!(chars, 2);
        assert!(lexed.toks.iter().any(|t| t.kind == TokKind::Lifetime));
    }

    #[test]
    fn pragmas_are_collected_with_lines() {
        let src = "let a = 1;\n// picbnn: allow(clock-seam) — bench timing\nlet b = 2;\n";
        let lexed = lex(src);
        assert_eq!(lexed.pragmas.len(), 1);
        assert_eq!(lexed.pragmas[0].line, 2);
        assert!(lexed.pragmas[0].text.contains("allow(clock-seam)"));
    }

    #[test]
    fn only_marker_leading_comments_are_pragma_candidates() {
        let src = "\
// picbnn: allow(clock-seam) — real pragma\n\
//! use picbnn::testkit::forall; — crate path in a doc comment\n\
/// the `picbnn:` marker explained in a doc comment\n\
// prose that mentions picbnn: mid-sentence\n\
// picbnn::engine — crate path at comment start\n";
        let lexed = lex(src);
        assert_eq!(lexed.pragmas.len(), 1, "pragmas: {:?}", lexed.pragmas);
        assert_eq!(lexed.pragmas[0].line, 1);
    }

    #[test]
    fn cfg_test_spans_cover_the_module() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let lexed = lex(src);
        assert_eq!(lexed.cfg_test_spans.len(), 1);
        assert!(lexed.in_test_span(4));
        assert!(!lexed.in_test_span(1));
        assert!(!lexed.in_test_span(6));
    }

    #[test]
    fn numbers_do_not_swallow_ranges() {
        let lexed = lex("for i in 0..n { v[i] = 1.5e3; }");
        let nums = lexed.toks.iter().filter(|t| t.kind == TokKind::Num).count();
        assert_eq!(nums, 2, "0 and 1.5e3");
    }
}
