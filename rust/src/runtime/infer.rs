//! Model-level PJRT inference: wraps the `{name}_infer.hlo.txt` artifact
//! (the full Algorithm-1 graph with weights as runtime parameters) behind
//! a batched classify API that matches the CAM pipeline's semantics.

use crate::bnn::model::MappedModel;
use crate::util::bitops::BitVec;

use super::engine::Engine;
use super::{RtError, RtResult};

/// AOT batch the artifacts were lowered at (python/compile/aot.py::BATCH).
pub const AOT_BATCH: usize = 64;

/// The Algorithm-1 inference graph, executed via PJRT.
pub struct InferEngine {
    engine: Engine,
    // flattened f32 parameter buffers (built once from the mapped model)
    w1: Vec<f32>,
    q1: Vec<f32>,
    w2: Vec<f32>,
    q2: Vec<f32>,
    schedule: Vec<f32>,
    n_in: usize,
    n_hidden: usize,
    n_seg: usize,
    n_classes: usize,
}

fn weights_to_f32(layer: &crate::bnn::model::MappedLayer) -> Vec<f32> {
    let mut out = Vec::with_capacity(layer.n_out() * layer.n_in());
    for r in 0..layer.n_out() {
        for c in 0..layer.n_in() {
            out.push(if layer.weights.get(r, c) { 1.0 } else { -1.0 });
        }
    }
    out
}

impl InferEngine {
    /// Load the artifact for `name` ("mnist"/"hg") and bind the model's
    /// parameters.
    pub fn load(name: &str, model: &MappedModel) -> RtResult<InferEngine> {
        let path = crate::artifacts_dir().join(format!("{name}_infer.hlo.txt"));
        let engine = Engine::load(&path)
            .map_err(|e| e.context(format!("load inference artifact for {name}")))?;
        if model.layers.len() != 2 {
            return Err(RtError::msg("artifact expects 2 layers"));
        }
        let l1 = &model.layers[0];
        let l2 = &model.layers[1];
        Ok(InferEngine {
            engine,
            w1: weights_to_f32(l1),
            q1: l1.q.iter().flatten().map(|&q| q as f32).collect(),
            w2: weights_to_f32(l2),
            q2: l2.q.iter().flatten().map(|&q| q as f32).collect(),
            schedule: model.schedule.iter().map(|&t| t as f32).collect(),
            n_in: l1.n_in(),
            n_hidden: l1.n_out(),
            n_seg: l1.n_seg(),
            n_classes: l2.n_out(),
        })
    }

    pub fn platform(&self) -> String {
        self.engine.platform()
    }

    /// Classify up to AOT_BATCH images; returns (votes, pred) per image.
    /// Short batches are padded (padding results are discarded).
    pub fn classify_batch(&self, images: &[BitVec]) -> RtResult<Vec<(Vec<u32>, usize)>> {
        if images.is_empty() {
            return Err(RtError::msg("empty batch"));
        }
        if images.len() > AOT_BATCH {
            return Err(RtError::msg(format!(
                "batch {} exceeds AOT batch {AOT_BATCH}",
                images.len()
            )));
        }
        let mut x = vec![1.0f32; AOT_BATCH * self.n_in];
        for (i, img) in images.iter().enumerate() {
            if img.len() != self.n_in {
                return Err(RtError::msg("image width mismatch"));
            }
            for c in 0..self.n_in {
                x[i * self.n_in + c] = if img.get(c) { 1.0 } else { -1.0 };
            }
        }
        let out = self.engine.run_f32(&[
            (&x, &[AOT_BATCH, self.n_in]),
            (&self.w1, &[self.n_hidden, self.n_in]),
            (&self.q1, &[self.n_seg, self.n_hidden]),
            (&self.w2, &[self.n_classes, self.n_hidden]),
            (&self.q2, &[1, self.n_classes]),
            (&self.schedule, &[self.schedule.len()]),
        ])?;
        if out.len() != 2 {
            return Err(RtError::msg("expected (votes, pred) outputs"));
        }
        let votes_flat = &out[0];
        let preds = &out[1];
        Ok(images
            .iter()
            .enumerate()
            .map(|(i, _)| {
                let votes: Vec<u32> = votes_flat[i * self.n_classes..(i + 1) * self.n_classes]
                    .iter()
                    .map(|&v| v as u32)
                    .collect();
                (votes, preds[i] as usize)
            })
            .collect())
    }

    /// Classify an arbitrary number of images, chunking at the AOT batch.
    pub fn classify_all(&self, images: &[BitVec]) -> RtResult<Vec<(Vec<u32>, usize)>> {
        let mut out = Vec::with_capacity(images.len());
        for chunk in images.chunks(AOT_BATCH) {
            out.extend(self.classify_batch(chunk)?);
        }
        Ok(out)
    }
}
