//! PJRT runtime: loads the AOT-lowered HLO text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client — the
//! functional-reference execution backend of the three-layer stack.
//!
//! Python never runs here: the artifacts are compiled once at build time
//! (`make artifacts`), and this module's `Engine` compiles the HLO text to
//! a PJRT executable at startup and serves requests from the rust event
//! loop.  Interchange is HLO *text* (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod engine;
pub mod infer;

pub use engine::Engine;
pub use infer::InferEngine;
