//! PJRT runtime: loads the AOT-lowered HLO text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client — the
//! functional-reference execution backend of the three-layer stack.
//!
//! Python never runs here: the artifacts are compiled once at build time
//! (`make artifacts`), and this module's `Engine` compiles the HLO text to
//! a PJRT executable at startup and serves requests from the rust event
//! loop.  Interchange is HLO *text* (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The offline build has no xla_extension toolchain, so the real engine is
//! gated behind the non-default `pjrt` cargo feature; the default build
//! ships a stub whose `load` reports the backend as unavailable.  Every
//! caller (benches, examples, the CLI) already treats a failing load as
//! "backend unavailable" and falls back to the CAM simulator.

pub mod engine;
pub mod infer;

pub use engine::Engine;
pub use infer::InferEngine;

/// Runtime-layer error: a rendered message chain (the offline build has no
/// `anyhow`; this carries the same context-wrapping ergonomics we need).
#[derive(Clone, Debug)]
pub struct RtError(String);

impl RtError {
    pub fn msg(m: impl Into<String>) -> Self {
        RtError(m.into())
    }

    /// Wrap with a context prefix (outermost first, like anyhow's chain).
    pub fn context(self, ctx: impl std::fmt::Display) -> Self {
        RtError(format!("{ctx}: {}", self.0))
    }
}

impl std::fmt::Display for RtError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RtError {}

/// Result alias for the runtime layer.
pub type RtResult<T> = Result<T, RtError>;
