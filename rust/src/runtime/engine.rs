//! Generic HLO-artifact execution: one compiled PJRT executable per
//! artifact, executed with f32 literals.
//!
//! Two implementations behind one API:
//! * `pjrt` feature **and** the vendored bindings present
//!   (`RUSTFLAGS="--cfg pjrt_bindings"`) — the real XLA CPU client
//!   (requires the `xla` bindings crate + xla_extension shared library
//!   at build time);
//! * otherwise — a stub whose `load` always fails with a clear
//!   "backend unavailable" error, which every call site treats as a skip.
//!
//! The split gate lets `cargo check --features pjrt` compile (and CI keep
//! the feature from rotting) on machines without the xla toolchain: the
//! feature opts into the backend, the cfg attests the bindings exist.

use std::path::Path;

use super::{RtError, RtResult};

#[cfg(all(feature = "pjrt", pjrt_bindings))]
mod real {
    use super::*;

    /// A compiled PJRT executable wrapping one HLO-text artifact.
    pub struct Engine {
        client: xla::PjRtClient,
        exe: xla::PjRtLoadedExecutable,
        path: String,
    }

    impl Engine {
        /// Load + compile an HLO text artifact on the CPU PJRT client.
        pub fn load(path: impl AsRef<Path>) -> RtResult<Engine> {
            let path = path.as_ref();
            let client = xla::PjRtClient::cpu()
                .map_err(|e| RtError::msg(e.to_string()).context("create PJRT CPU client"))?;
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| {
                    RtError::msg(e.to_string())
                        .context(format!("parse HLO text {}", path.display()))
                })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| {
                RtError::msg(e.to_string()).context(format!("compile {}", path.display()))
            })?;
            Ok(Engine {
                client,
                exe,
                path: path.display().to_string(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn path(&self) -> &str {
            &self.path
        }

        /// Execute with f32 inputs of the given shapes; returns the outputs
        /// of the result tuple as flat f32 vectors (jax lowers with
        /// return_tuple=True, so the single result is a tuple literal).
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> RtResult<Vec<Vec<f32>>> {
            let wrap = |e: xla::Error, ctx: &str| RtError::msg(e.to_string()).context(ctx);
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, shape)| {
                    let lit = xla::Literal::vec1(data);
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims).map_err(|e| wrap(e, "reshape input literal"))
                })
                .collect::<RtResult<_>>()?;
            let mut result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| wrap(e, "execute"))?[0][0]
                .to_literal_sync()
                .map_err(|e| wrap(e, "fetch result"))?;
            let tuple = result
                .decompose_tuple()
                .map_err(|e| wrap(e, "decompose result tuple"))?;
            tuple
                .into_iter()
                .map(|lit| {
                    // outputs may be f32 or s32; normalise to f32
                    match lit.ty() {
                        Ok(xla::ElementType::F32) => {
                            lit.to_vec::<f32>().map_err(|e| wrap(e, "f32 out"))
                        }
                        Ok(xla::ElementType::S32) => Ok(lit
                            .to_vec::<i32>()
                            .map_err(|e| wrap(e, "s32 out"))?
                            .into_iter()
                            .map(|v| v as f32)
                            .collect()),
                        other => Err(RtError::msg(format!(
                            "unsupported output element type {other:?}"
                        ))),
                    }
                })
                .collect()
        }
    }
}

#[cfg(not(all(feature = "pjrt", pjrt_bindings)))]
mod stub {
    use super::*;

    /// Offline stand-in: carries the API surface of the PJRT engine but
    /// cannot be constructed — `load` reports the backend as unavailable.
    pub struct Engine {
        // never constructed; kept so the API surface matches the real engine
        #[allow(dead_code)]
        path: String,
    }

    impl Engine {
        pub fn load(path: impl AsRef<Path>) -> RtResult<Engine> {
            Err(RtError::msg(format!(
                "PJRT backend unavailable: built without the `pjrt` feature \
                 or the vendored xla bindings (--cfg pjrt_bindings) \
                 (artifact {})",
                path.as_ref().display()
            )))
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn path(&self) -> &str {
            &self.path
        }

        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> RtResult<Vec<Vec<f32>>> {
            Err(RtError::msg("PJRT backend unavailable"))
        }
    }
}

#[cfg(all(feature = "pjrt", pjrt_bindings))]
pub use real::Engine;
#[cfg(not(all(feature = "pjrt", pjrt_bindings)))]
pub use stub::Engine;

#[cfg(all(test, feature = "pjrt", pjrt_bindings))]
mod tests {
    use super::*;

    fn artifact(name: &str) -> std::path::PathBuf {
        crate::artifacts_dir().join(name)
    }

    #[test]
    fn xnor_dot_artifact_matches_packed_reference() {
        let path = artifact("xnor_dot.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: {} not built", path.display());
            return;
        }
        let eng = Engine::load(&path).unwrap();
        // shapes fixed at lowering: x (64,1024), w (128,1024)
        let mut rng = crate::util::rng::Rng::new(3, 3);
        let x: Vec<f32> = (0..64 * 1024)
            .map(|_| if rng.chance(0.5) { 1.0 } else { -1.0 })
            .collect();
        let w: Vec<f32> = (0..128 * 1024)
            .map(|_| if rng.chance(0.5) { 1.0 } else { -1.0 })
            .collect();
        let out = eng
            .run_f32(&[(&x, &[64, 1024]), (&w, &[128, 1024])])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 64 * 128);
        // check a few entries against the packed bitops reference
        use crate::util::bitops::BitVec;
        let to_bv = |v: &[f32]| {
            let pm: Vec<i8> = v.iter().map(|&f| if f > 0.0 { 1 } else { -1 }).collect();
            BitVec::from_pm1(&pm)
        };
        for &(i, j) in &[(0usize, 0usize), (5, 100), (63, 127)] {
            let xb = to_bv(&x[i * 1024..(i + 1) * 1024]);
            let wb = to_bv(&w[j * 1024..(j + 1) * 1024]);
            let want = xb.dot_pm1(&wb) as f32;
            assert_eq!(out[0][i * 128 + j], want, "entry ({i},{j})");
        }
    }

    #[test]
    fn matchline_artifact_matches_analog_nominal() {
        let path = artifact("matchline_fire.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: {} not built", path.display());
            return;
        }
        let eng = Engine::load(&path).unwrap();
        // shapes fixed at lowering: m (256,64), v (3,)
        let mut rng = crate::util::rng::Rng::new(5, 9);
        let m: Vec<f32> = (0..256 * 64).map(|_| rng.below(257) as f32).collect();
        let v = [0.775f32, 0.6, 1.1];
        let out = eng.run_f32(&[(&m, &[256, 64]), (&v, &[3])]).unwrap();
        let model = crate::analog::MatchlineModel::new(256, crate::analog::Pvt::nominal());
        let volts = crate::analog::Voltages::new(v[0] as f64, v[1] as f64, v[2] as f64);
        let tol = model.hd_tolerance(&volts);
        for (idx, &fire) in out[0].iter().enumerate() {
            let mm = m[idx] as f64;
            if (mm - tol).abs() < 0.25 {
                continue; // f32-vs-f64 boundary cell
            }
            let want = if mm <= tol { 1.0 } else { 0.0 };
            assert_eq!(fire, want, "m={mm} tol={tol}");
        }
    }
}

#[cfg(all(test, not(all(feature = "pjrt", pjrt_bindings))))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_load_reports_unavailable() {
        let err = Engine::load("nonexistent.hlo.txt").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("PJRT backend unavailable"), "{msg}");
        assert!(msg.contains("pjrt"), "{msg}");
    }
}
