//! Two-pass RV32I assembler for the control firmware: labels, the base
//! ISA, and the common pseudo-instructions (li, la, mv, j, call, ret,
//! beqz/bnez, nop).  Enough to write readable firmware in-tree without an
//! external toolchain.

use std::collections::BTreeMap;

/// Assemble source into a little-endian image loaded at address 0.
pub fn assemble(src: &str) -> Result<Vec<u8>, String> {
    let lines = tokenize(src)?;
    // pass 1: label addresses (li/la expand to 2 words conservatively)
    let mut labels: BTreeMap<String, u32> = BTreeMap::new();
    let mut addr = 0u32;
    for line in &lines {
        for label in &line.labels {
            if labels.insert(label.clone(), addr).is_some() {
                return Err(format!("duplicate label {label}"));
            }
        }
        if let Some(op) = &line.op {
            addr += 4 * words_for_op(op);
        }
    }
    // pass 2: encode
    let mut out = Vec::new();
    let mut addr = 0u32;
    for line in &lines {
        if let Some(op) = &line.op {
            let words = encode(op, &line.args, addr, &labels)
                .map_err(|e| format!("line {}: {e}", line.lineno))?;
            for w in words {
                out.extend_from_slice(&w.to_le_bytes());
            }
            addr = out.len() as u32;
        }
    }
    Ok(out)
}

struct Line {
    lineno: usize,
    labels: Vec<String>,
    op: Option<String>,
    args: Vec<String>,
}

fn tokenize(src: &str) -> Result<Vec<Line>, String> {
    let mut out = Vec::new();
    for (lineno, raw) in src.lines().enumerate() {
        let line = raw.split('#').next().unwrap().trim();
        if line.is_empty() {
            continue;
        }
        let mut labels = Vec::new();
        let mut rest = line;
        while let Some(idx) = rest.find(':') {
            let (head, tail) = rest.split_at(idx);
            if head.contains(char::is_whitespace) {
                break;
            }
            labels.push(head.trim().to_string());
            rest = tail[1..].trim();
        }
        if rest.is_empty() {
            out.push(Line {
                lineno: lineno + 1,
                labels,
                op: None,
                args: Vec::new(),
            });
            continue;
        }
        let (op, args_str) = match rest.split_once(char::is_whitespace) {
            Some((o, a)) => (o, a),
            None => (rest, ""),
        };
        let args: Vec<String> = args_str
            .split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect();
        out.push(Line {
            lineno: lineno + 1,
            labels,
            op: Some(op.to_lowercase()),
            args,
        });
    }
    Ok(out)
}

fn words_for_op(op: &str) -> u32 {
    match op {
        "li" | "la" | "call" => 2, // worst case; encoder pads with nop
        _ => 1,
    }
}

fn reg(name: &str) -> Result<u32, String> {
    let abi = [
        ("zero", 0),
        ("ra", 1),
        ("sp", 2),
        ("gp", 3),
        ("tp", 4),
        ("t0", 5),
        ("t1", 6),
        ("t2", 7),
        ("s0", 8),
        ("fp", 8),
        ("s1", 9),
        ("a0", 10),
        ("a1", 11),
        ("a2", 12),
        ("a3", 13),
        ("a4", 14),
        ("a5", 15),
        ("a6", 16),
        ("a7", 17),
        ("s2", 18),
        ("s3", 19),
        ("s4", 20),
        ("s5", 21),
        ("s6", 22),
        ("s7", 23),
        ("s8", 24),
        ("s9", 25),
        ("s10", 26),
        ("s11", 27),
        ("t3", 28),
        ("t4", 29),
        ("t5", 30),
        ("t6", 31),
    ];
    if let Some(&(_, n)) = abi.iter().find(|&&(a, _)| a == name) {
        return Ok(n);
    }
    if let Some(n) = name.strip_prefix('x').and_then(|s| s.parse::<u32>().ok()) {
        if n < 32 {
            return Ok(n);
        }
    }
    Err(format!("bad register {name:?}"))
}

fn imm(s: &str, labels: &BTreeMap<String, u32>) -> Result<i64, String> {
    if let Some(v) = labels.get(s) {
        return Ok(*v as i64);
    }
    let (neg, body) = match s.strip_prefix('-') {
        Some(b) => (true, b),
        None => (false, s),
    };
    let v = if let Some(hex) = body.strip_prefix("0x") {
        i64::from_str_radix(hex, 16).map_err(|e| e.to_string())?
    } else {
        body.parse::<i64>().map_err(|_| format!("bad immediate {s:?}"))?
    };
    Ok(if neg { -v } else { v })
}

/// Parse "imm(reg)" memory operands.
fn mem_operand(s: &str, labels: &BTreeMap<String, u32>) -> Result<(i64, u32), String> {
    let open = s.find('(').ok_or_else(|| format!("bad mem operand {s:?}"))?;
    let close = s.rfind(')').ok_or_else(|| format!("bad mem operand {s:?}"))?;
    let off = if open == 0 { 0 } else { imm(&s[..open], labels)? };
    let r = reg(&s[open + 1..close])?;
    Ok((off, r))
}

fn enc_r(f7: u32, rs2: u32, rs1: u32, f3: u32, rd: u32, op: u32) -> u32 {
    (f7 << 25) | (rs2 << 20) | (rs1 << 15) | (f3 << 12) | (rd << 7) | op
}

fn enc_i(imm: i64, rs1: u32, f3: u32, rd: u32, op: u32) -> Result<u32, String> {
    if !(-2048..=2047).contains(&imm) {
        return Err(format!("I-immediate {imm} out of range"));
    }
    Ok((((imm as u32) & 0xfff) << 20) | (rs1 << 15) | (f3 << 12) | (rd << 7) | op)
}

fn enc_s(imm: i64, rs2: u32, rs1: u32, f3: u32, op: u32) -> Result<u32, String> {
    if !(-2048..=2047).contains(&imm) {
        return Err(format!("S-immediate {imm} out of range"));
    }
    let u = imm as u32;
    Ok(((u >> 5 & 0x7f) << 25) | (rs2 << 20) | (rs1 << 15) | (f3 << 12) | ((u & 0x1f) << 7) | op)
}

fn enc_b(imm: i64, rs2: u32, rs1: u32, f3: u32) -> Result<u32, String> {
    if imm % 2 != 0 || !(-4096..=4094).contains(&imm) {
        return Err(format!("branch offset {imm} invalid"));
    }
    let u = imm as u32;
    Ok(((u >> 12 & 1) << 31)
        | ((u >> 5 & 0x3f) << 25)
        | (rs2 << 20)
        | (rs1 << 15)
        | (f3 << 12)
        | ((u >> 1 & 0xf) << 8)
        | ((u >> 11 & 1) << 7)
        | 0x63)
}

fn enc_j(imm: i64, rd: u32) -> Result<u32, String> {
    if imm % 2 != 0 || !(-(1 << 20)..(1 << 20)).contains(&imm) {
        return Err(format!("jump offset {imm} invalid"));
    }
    let u = imm as u32;
    Ok(((u >> 20 & 1) << 31)
        | ((u >> 1 & 0x3ff) << 21)
        | ((u >> 11 & 1) << 20)
        | ((u >> 12 & 0xff) << 12)
        | (rd << 7)
        | 0x6f)
}

fn enc_u(value: i64, rd: u32, op: u32) -> u32 {
    ((value as u32) & 0xffff_f000) | (rd << 7) | op
}

/// Expand `li rd, imm32` / `la` into lui+addi (always two words; nop pad).
fn expand_li(rd: u32, value: i64) -> Vec<u32> {
    let v = value as i32;
    let lo = ((v << 20) >> 20) as i64; // sign-extended low 12
    let hi = (v as i64 - lo) as i32 as u32; // upper 20 with carry folded
    let mut out = Vec::new();
    if hi != 0 {
        out.push(enc_u(hi as i64, rd, 0x37)); // lui
        if lo != 0 {
            out.push(enc_i(lo, rd, 0, rd, 0x13).unwrap()); // addi rd, rd, lo
        }
    } else {
        out.push(enc_i(lo, 0, 0, rd, 0x13).unwrap()); // addi rd, x0, lo
    }
    while out.len() < 2 {
        out.push(enc_i(0, 0, 0, 0, 0x13).unwrap()); // nop pad (fixed size)
    }
    out
}

fn encode(
    op: &str,
    args: &[String],
    pc: u32,
    labels: &BTreeMap<String, u32>,
) -> Result<Vec<u32>, String> {
    let a = |i: usize| -> Result<&str, String> {
        args.get(i)
            .map(String::as_str)
            .ok_or_else(|| format!("{op}: missing operand {i}"))
    };
    let branch_to = |target: &str| -> Result<i64, String> {
        let t = imm(target, labels)?;
        Ok(t - pc as i64)
    };
    let one = |w: u32| Ok(vec![w]);
    match op {
        // --- U/J ---
        "lui" => one(enc_u(imm(a(1)?, labels)? << 12, reg(a(0)?)?, 0x37)),
        "auipc" => one(enc_u(imm(a(1)?, labels)? << 12, reg(a(0)?)?, 0x17)),
        "jal" => {
            let (rd, target) = if args.len() == 1 {
                (1, a(0)?)
            } else {
                (reg(a(0)?)?, a(1)?)
            };
            one(enc_j(branch_to(target)?, rd)?)
        }
        "jalr" => {
            let (off, rs1) = mem_operand(a(1)?, labels)?;
            one(enc_i(off, rs1, 0, reg(a(0)?)?, 0x67)?)
        }
        // --- branches ---
        "beq" | "bne" | "blt" | "bge" | "bltu" | "bgeu" => {
            let f3 = match op {
                "beq" => 0,
                "bne" => 1,
                "blt" => 4,
                "bge" => 5,
                "bltu" => 6,
                _ => 7,
            };
            one(enc_b(branch_to(a(2)?)?, reg(a(1)?)?, reg(a(0)?)?, f3)?)
        }
        "beqz" => one(enc_b(branch_to(a(1)?)?, 0, reg(a(0)?)?, 0)?),
        "bnez" => one(enc_b(branch_to(a(1)?)?, 0, reg(a(0)?)?, 1)?),
        // --- loads/stores ---
        "lb" | "lh" | "lw" | "lbu" | "lhu" => {
            let f3 = match op {
                "lb" => 0,
                "lh" => 1,
                "lw" => 2,
                "lbu" => 4,
                _ => 5,
            };
            let (off, rs1) = mem_operand(a(1)?, labels)?;
            one(enc_i(off, rs1, f3, reg(a(0)?)?, 0x03)?)
        }
        "sb" | "sh" | "sw" => {
            let f3 = match op {
                "sb" => 0,
                "sh" => 1,
                _ => 2,
            };
            let (off, rs1) = mem_operand(a(1)?, labels)?;
            one(enc_s(off, reg(a(0)?)?, rs1, f3, 0x23)?)
        }
        // --- ALU imm ---
        "addi" | "slti" | "sltiu" | "xori" | "ori" | "andi" => {
            let f3 = match op {
                "addi" => 0,
                "slti" => 2,
                "sltiu" => 3,
                "xori" => 4,
                "ori" => 6,
                _ => 7,
            };
            one(enc_i(imm(a(2)?, labels)?, reg(a(1)?)?, f3, reg(a(0)?)?, 0x13)?)
        }
        "slli" | "srli" | "srai" => {
            let sh = imm(a(2)?, labels)? as u32 & 31;
            let f7 = if op == "srai" { 0x20 } else { 0 };
            let f3 = if op == "slli" { 1 } else { 5 };
            one(enc_r(f7, sh, reg(a(1)?)?, f3, reg(a(0)?)?, 0x13))
        }
        // --- ALU reg ---
        "add" | "sub" | "sll" | "slt" | "sltu" | "xor" | "srl" | "sra" | "or" | "and" => {
            let (f3, f7) = match op {
                "add" => (0, 0x00),
                "sub" => (0, 0x20),
                "sll" => (1, 0x00),
                "slt" => (2, 0x00),
                "sltu" => (3, 0x00),
                "xor" => (4, 0x00),
                "srl" => (5, 0x00),
                "sra" => (5, 0x20),
                "or" => (6, 0x00),
                _ => (7, 0x00),
            };
            one(enc_r(f7, reg(a(2)?)?, reg(a(1)?)?, f3, reg(a(0)?)?, 0x33))
        }
        // --- system ---
        "ecall" => one(0x0000_0073),
        "ebreak" => one(0x0010_0073),
        "fence" => one(0x0000_000f),
        // --- pseudo ---
        "nop" => one(enc_i(0, 0, 0, 0, 0x13)?),
        "mv" => one(enc_i(0, reg(a(1)?)?, 0, reg(a(0)?)?, 0x13)?),
        "not" => one(enc_i(-1, reg(a(1)?)?, 4, reg(a(0)?)?, 0x13)?),
        "neg" => one(enc_r(0x20, reg(a(1)?)?, 0, 0, reg(a(0)?)?, 0x33)),
        "j" => one(enc_j(branch_to(a(0)?)?, 0)?),
        "jr" => one(enc_i(0, reg(a(0)?)?, 0, 0, 0x67)?),
        "ret" => one(enc_i(0, 1, 0, 0, 0x67)?),
        "li" | "la" => Ok(expand_li(reg(a(0)?)?, imm(a(1)?, labels)?)),
        "call" => {
            // 2 words: auipc+jalr would be general; label fits jal here,
            // pad with nop to keep the fixed 2-word footprint of pass 1
            let target = branch_to(a(0)?)?;
            Ok(vec![enc_j(target, 1)?, enc_i(0, 0, 0, 0, 0x13)?])
        }
        other => Err(format!("unknown mnemonic {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encodes_known_words() {
        // addi x1, x0, 5  = 0x00500093
        let img = assemble("addi x1, x0, 5\n").unwrap();
        assert_eq!(u32::from_le_bytes(img[..4].try_into().unwrap()), 0x0050_0093);
        // add x3, x1, x2 = 0x002081B3
        let img = assemble("add x3, x1, x2\n").unwrap();
        assert_eq!(u32::from_le_bytes(img[..4].try_into().unwrap()), 0x0020_81b3);
        // sw x2, 8(x1) = 0x0020A423
        let img = assemble("sw x2, 8(x1)\n").unwrap();
        assert_eq!(u32::from_le_bytes(img[..4].try_into().unwrap()), 0x0020_a423);
    }

    #[test]
    fn labels_resolve_forward_and_back() {
        let img = assemble(
            "start: addi a0, zero, 1\n\
             j end\n\
             addi a0, zero, 99\n\
             end: ecall\n",
        )
        .unwrap();
        assert_eq!(img.len(), 4 * 4);
    }

    #[test]
    fn li_expands_to_fixed_two_words() {
        for v in ["5", "-5", "0x12345678", "-2048", "2047", "0x7ffff000"] {
            let img = assemble(&format!("li a0, {v}\n")).unwrap();
            assert_eq!(img.len(), 8, "li {v}");
        }
    }

    #[test]
    fn duplicate_label_rejected() {
        assert!(assemble("x: nop\nx: nop\n").is_err());
    }

    #[test]
    fn unknown_mnemonic_rejected() {
        assert!(assemble("frobnicate a0, a1\n").is_err());
    }

    #[test]
    fn immediate_range_checked() {
        assert!(assemble("addi a0, a0, 5000\n").is_err());
    }

    #[test]
    fn abi_and_numeric_registers_equivalent() {
        let a = assemble("add a0, a1, a2\n").unwrap();
        let b = assemble("add x10, x11, x12\n").unwrap();
        assert_eq!(a, b);
    }
}
