//! Memory-mapped CAM device interface: the bus the control CPU drives
//! (paper Fig. 6: the RISC-V SoC wraps the PiC-BNN macro).
//!
//! Register map (word offsets from MMIO_BASE):
//! ```text
//! 0x000 CONFIG   w: 0/1/2 -> 512x256 / 1024x128 / 2048x64 (clears array)
//! 0x004 ROW_ADDR w: row index for CMD_WRITE_ROW
//! 0x008 VREF_MV  w: V_ref in millivolts
//! 0x00C VEVAL_MV w: V_eval in millivolts
//! 0x010 VST_MV   w: V_st in millivolts
//! 0x014 CMD      w: 1 = write row (data window -> row), 2 = search
//!                   (data window = query, fires -> fire window),
//!                   3 = retune rails to the *_MV registers
//! 0x018 STATUS   r: 1 = ready (the model has no multi-cycle busy states)
//! 0x01C CYCLES   r: device cycle counter (low 32 bits)
//! 0x020 TOL_Q8   r: current nominal HD tolerance, 24.8 fixed point
//! 0x100-0x1FF    DATA window: row/query bits (up to 2048 = 64 words)
//! 0x200-0x21F    FIRE window: per-row MLSA outputs (up to 256 rows)
//! ```

use crate::analog::Voltages;
use crate::cam::{CamArray, CamConfig};
use crate::util::bitops::BitVec;

use super::cpu::MmioDevice;

pub const REG_CONFIG: u32 = 0x000;
pub const REG_ROW_ADDR: u32 = 0x004;
pub const REG_VREF: u32 = 0x008;
pub const REG_VEVAL: u32 = 0x00c;
pub const REG_VST: u32 = 0x010;
pub const REG_CMD: u32 = 0x014;
pub const REG_STATUS: u32 = 0x018;
pub const REG_CYCLES: u32 = 0x01c;
pub const REG_TOL_Q8: u32 = 0x020;
pub const DATA_BASE: u32 = 0x100;
pub const DATA_WORDS: u32 = 64; // 2048 bits
pub const FIRE_BASE: u32 = 0x200;
pub const FIRE_WORDS: u32 = 8; // 256 rows

pub const CMD_WRITE_ROW: u32 = 1;
pub const CMD_SEARCH: u32 = 2;
pub const CMD_RETUNE: u32 = 3;

/// The CAM macro behind the register file.
pub struct CamMmio {
    pub cam: CamArray,
    row_addr: u32,
    vref_mv: u32,
    veval_mv: u32,
    vst_mv: u32,
    data: [u32; DATA_WORDS as usize],
    fires: [u32; FIRE_WORDS as usize],
    scratch_m: Vec<u32>,
    scratch_f: Vec<bool>,
}

impl CamMmio {
    pub fn new(cam: CamArray) -> Self {
        CamMmio {
            cam,
            row_addr: 0,
            vref_mv: 1200,
            veval_mv: 1200,
            vst_mv: 1200,
            data: [0; DATA_WORDS as usize],
            fires: [0; FIRE_WORDS as usize],
            scratch_m: Vec::new(),
            scratch_f: Vec::new(),
        }
    }

    fn data_bits(&self, width: usize) -> BitVec {
        let mut v = BitVec::zeros(width);
        for i in 0..width {
            let w = self.data[i / 32];
            if (w >> (i % 32)) & 1 == 1 {
                v.set(i, true);
            }
        }
        v
    }

    fn execute(&mut self, cmd: u32) {
        let width = self.cam.config().width();
        match cmd {
            CMD_WRITE_ROW => {
                let row = self.row_addr as usize % self.cam.config().rows();
                let bits = self.data_bits(width);
                self.cam.write_row(row, &bits);
            }
            CMD_SEARCH => {
                let query = self.data_bits(width);
                let mut m = std::mem::take(&mut self.scratch_m);
                let mut f = std::mem::take(&mut self.scratch_f);
                self.cam.search_into(&query, &mut m, &mut f);
                self.fires = [0; FIRE_WORDS as usize];
                for (r, &fire) in f.iter().enumerate() {
                    if fire && r < 256 {
                        self.fires[r / 32] |= 1 << (r % 32);
                    }
                }
                self.scratch_m = m;
                self.scratch_f = f;
            }
            CMD_RETUNE => {
                self.cam.set_voltages(Voltages::new(
                    self.vref_mv as f64 / 1e3,
                    self.veval_mv as f64 / 1e3,
                    self.vst_mv as f64 / 1e3,
                ));
            }
            _ => {} // unknown commands ignore (write-1-to-poke style bus)
        }
    }
}

impl MmioDevice for CamMmio {
    fn read(&mut self, offset: u32) -> u32 {
        match offset {
            REG_STATUS => 1,
            REG_CYCLES => self.cam.clock.cycles as u32,
            REG_TOL_Q8 => (self.cam.current_tolerance() * 256.0) as u32,
            REG_VREF => self.vref_mv,
            REG_VEVAL => self.veval_mv,
            REG_VST => self.vst_mv,
            o if (DATA_BASE..DATA_BASE + 4 * DATA_WORDS).contains(&o) => {
                self.data[((o - DATA_BASE) / 4) as usize]
            }
            o if (FIRE_BASE..FIRE_BASE + 4 * FIRE_WORDS).contains(&o) => {
                self.fires[((o - FIRE_BASE) / 4) as usize]
            }
            _ => 0,
        }
    }

    fn write(&mut self, offset: u32, value: u32) {
        match offset {
            REG_CONFIG => {
                let cfg = match value {
                    0 => CamConfig::W512x256,
                    1 => CamConfig::W1024x128,
                    _ => CamConfig::W2048x64,
                };
                self.cam.reconfigure(cfg);
            }
            REG_ROW_ADDR => self.row_addr = value,
            REG_VREF => self.vref_mv = value,
            REG_VEVAL => self.veval_mv = value,
            REG_VST => self.vst_mv = value,
            REG_CMD => self.execute(value),
            o if (DATA_BASE..DATA_BASE + 4 * DATA_WORDS).contains(&o) => {
                self.data[((o - DATA_BASE) / 4) as usize] = value;
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn device() -> CamMmio {
        CamMmio::new(CamArray::nominal(CamConfig::W512x256))
    }

    #[test]
    fn write_row_and_exact_search_via_registers() {
        let mut dev = device();
        // row 3 := data window pattern
        for w in 0..16 {
            dev.write(DATA_BASE + 4 * w, 0xdead_beef ^ w);
        }
        dev.write(REG_ROW_ADDR, 3);
        dev.write(REG_CMD, CMD_WRITE_ROW);
        // exact search for the same pattern
        dev.write(REG_VREF, 1200);
        dev.write(REG_VEVAL, 1200);
        dev.write(REG_VST, 1200);
        dev.write(REG_CMD, CMD_RETUNE);
        dev.write(REG_CMD, CMD_SEARCH);
        assert_eq!(dev.read(FIRE_BASE) & (1 << 3), 1 << 3, "row 3 fires");
        assert_eq!(dev.read(FIRE_BASE) & !(1 << 3), 0, "only row 3");
        // flip one query bit -> no match at zero tolerance
        dev.write(DATA_BASE, (0xdead_beefu32) ^ 1);
        dev.write(REG_CMD, CMD_SEARCH);
        assert_eq!(dev.read(FIRE_BASE), 0);
    }

    #[test]
    fn retune_changes_reported_tolerance() {
        let mut dev = device();
        dev.write(REG_VREF, 1200);
        dev.write(REG_VEVAL, 1200);
        dev.write(REG_VST, 1200);
        dev.write(REG_CMD, CMD_RETUNE);
        let t0 = dev.read(REG_TOL_Q8);
        dev.write(REG_VREF, 700);
        dev.write(REG_VEVAL, 450);
        dev.write(REG_VST, 1100);
        dev.write(REG_CMD, CMD_RETUNE);
        let t1 = dev.read(REG_TOL_Q8);
        assert_eq!(t0, 0);
        assert!(t1 > 256, "tolerance should exceed 1.0 (q8): {t1}");
    }

    #[test]
    fn cycles_advance_with_commands() {
        let mut dev = device();
        let c0 = dev.read(REG_CYCLES);
        dev.write(REG_CMD, CMD_SEARCH);
        dev.write(REG_CMD, CMD_SEARCH);
        assert_eq!(dev.read(REG_CYCLES), c0 + 2);
    }

    #[test]
    fn config_write_reconfigures() {
        let mut dev = device();
        dev.write(REG_CONFIG, 2);
        assert_eq!(dev.cam.config(), CamConfig::W2048x64);
    }
}
