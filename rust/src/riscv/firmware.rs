//! Control firmware: the Algorithm-1 threshold sweep as an RV32I program
//! driving the CAM through its register file — the end-to-end proof that
//! the SoC control plane (paper [41]) needs nothing but binary searches
//! and register writes: no multiplier, no float unit, no popcount.
//!
//! RAM layout (addresses in the CPU's RAM space):
//! ```text
//! 0x2000  u32 K            number of schedule entries
//! 0x2004  u32 n_classes    classes (≤ 32: votes read fires word 0)
//! 0x2010  u32 × 3K         voltage table: (vref_mv, veval_mv, vst_mv) × K
//! 0x3000  u32 × n_classes  vote accumulators (firmware output)
//! ```
//! The host pokes the query into the device data window beforehand; the
//! firmware retunes, searches, and accumulates votes per class.

use crate::accel::CalibratedPoint;
use crate::util::bitops::BitVec;

use super::asm::assemble;
use super::cpu::{Cpu, Fault};
use super::mmio::{CamMmio, DATA_BASE};

/// The sweep program (see module docs for the RAM contract).
pub const SWEEP_ASM: &str = "\
    li   s0, 0x40000000      # MMIO base
    li   t0, 0x2000
    lw   s2, 0(t0)           # K
    lw   s3, 4(t0)           # n_classes
    li   s4, 0x2010          # voltage table ptr
    li   s5, 0x3000          # votes ptr
    li   s1, 0               # k = 0
sweep:
    lw   t1, 0(s4)
    sw   t1, 8(s0)           # VREF_MV
    lw   t1, 4(s4)
    sw   t1, 12(s0)          # VEVAL_MV
    lw   t1, 8(s4)
    sw   t1, 16(s0)          # VST_MV
    li   t1, 3
    sw   t1, 20(s0)          # CMD = retune
    li   t1, 2
    sw   t1, 20(s0)          # CMD = search
    li   t6, 0x40000200
    lw   t2, 0(t6)           # fires word 0
    li   t3, 0               # class c = 0
    mv   t4, s5
vote_loop:
    andi t5, t2, 1
    beqz t5, no_vote
    lw   t6, 0(t4)
    addi t6, t6, 1
    sw   t6, 0(t4)
no_vote:
    srli t2, t2, 1
    addi t4, t4, 4
    addi t3, t3, 1
    bne  t3, s3, vote_loop
    addi s4, s4, 12
    addi s1, s1, 1
    bne  s1, s2, sweep
    ecall
";

/// Run the sweep firmware for one query; returns per-class votes.
///
/// `points` are the calibrated operating points for the schedule (their
/// voltages are quantized to the same 1 mV grid the registers carry), and
/// the query must already match the device's configured word width.
pub fn run_sweep(
    dev: &mut CamMmio,
    points: &[CalibratedPoint],
    n_classes: usize,
    query: &BitVec,
) -> Result<(Vec<u32>, u64), Fault> {
    assert!(n_classes <= 32, "firmware reads fires word 0 only");
    // poke the query into the device data window
    use super::cpu::MmioDevice;
    for i in 0..query.len().div_ceil(32) {
        let mut w = 0u32;
        for b in 0..32 {
            let idx = i * 32 + b;
            if idx < query.len() && query.get(idx) {
                w |= 1 << b;
            }
        }
        dev.write(DATA_BASE + 4 * i as u32, w);
    }
    // assemble + load program and parameter block
    let image = assemble(SWEEP_ASM).expect("firmware assembles");
    let mut cpu = Cpu::with_device(256 * 1024, dev);
    cpu.load(0, &image);
    let mut params = Vec::new();
    params.extend_from_slice(&(points.len() as u32).to_le_bytes());
    params.extend_from_slice(&(n_classes as u32).to_le_bytes());
    cpu.load(0x2000, &params);
    let mut table = Vec::new();
    for p in points {
        for v in [p.voltages.vref, p.voltages.veval, p.voltages.vst] {
            table.extend_from_slice(&((v * 1e3).round() as u32).to_le_bytes());
        }
    }
    cpu.load(0x2010, &table);
    let instret = cpu.run(4_000_000)?;
    let votes = (0..n_classes)
        .map(|c| {
            let a = 0x3000 + 4 * c;
            u32::from_le_bytes(cpu.ram[a..a + 4].try_into().unwrap())
        })
        .collect();
    Ok((votes, instret))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::VoltageController;
    use crate::analog::Pvt;
    use crate::bnn::infer::{digital_output_hd, sweep_votes};
    use crate::bnn::mapping::{program_row, segment_query};
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::cam::{CamArray, CamConfig, NoiseMode};
    use crate::riscv::cpu::MmioDevice;
    use crate::riscv::mmio::{CMD_WRITE_ROW, REG_CMD, REG_ROW_ADDR};
    use crate::util::rng::Rng;

    #[test]
    fn firmware_sweep_matches_digital_reference() {
        // map a tiny output layer (n_in=128 -> fits 512-wide words with the
        // fixture's 256-cell seg_width extended by matching spares)
        let model = tiny_model(128, 16, 8, 71);
        let out = &model.layers[1]; // 8 classes × 16 inputs, width ≥ 64
        let cfg = CamConfig::W512x256;
        let mut dev = CamMmio::new(CamArray::new(
            cfg,
            Pvt::nominal(),
            NoiseMode::Nominal,
            0,
        ));
        // program class rows through the register file (as the CPU would)
        let width = cfg.width();
        for j in 0..out.n_out() {
            let row = program_row(out, 0, j);
            // extend to the physical width with matching '1' spares
            let mut bits = crate::util::bitops::BitVec::ones(width);
            for i in 0..row.len() {
                if !row.get(i) {
                    bits.set(i, false);
                }
            }
            for w in 0..width.div_ceil(32) {
                let mut word = 0u32;
                for b in 0..32 {
                    let idx = w * 32 + b;
                    if idx < width && bits.get(idx) {
                        word |= 1 << b;
                    }
                }
                dev.write(DATA_BASE + 4 * w as u32, word);
            }
            dev.write(REG_ROW_ADDR, j as u32);
            dev.write(REG_CMD, CMD_WRITE_ROW);
        }
        // calibrate a short schedule on the physical width
        let ctl = VoltageController::new(width, Pvt::nominal());
        let targets: Vec<u32> = (0..=16).step_by(2).collect();
        let points = ctl.calibrate_schedule(&targets);

        // a random hidden activation vector
        let mut rng = Rng::new(9, 9);
        let mut h = crate::util::bitops::BitVec::zeros(out.n_in());
        for i in 0..out.n_in() {
            h.set(i, rng.chance(0.5));
        }
        let narrow = segment_query(out, 0, &h);
        let mut query = crate::util::bitops::BitVec::ones(width);
        for i in 0..narrow.len() {
            if !narrow.get(i) {
                query.set(i, false);
            }
        }

        let (votes, instret) =
            run_sweep(&mut dev, &points, out.n_out(), &query).expect("firmware runs");
        // digital reference: HD + threshold sweep
        let hd = digital_output_hd(out, &h);
        let sched: Vec<i32> = targets.iter().map(|&t| t as i32).collect();
        let want = sweep_votes(&hd, &sched);
        assert_eq!(votes, want, "firmware votes vs digital reference");
        assert!(instret > 100, "firmware actually executed ({instret} insns)");
    }

    #[test]
    fn firmware_is_compact() {
        let image = assemble(SWEEP_ASM).unwrap();
        // the whole control loop fits in a few hundred bytes — the point of
        // the end-to-end-binary design: the CPU never does arithmetic wider
        // than an increment
        assert!(image.len() < 512, "{} bytes", image.len());
    }
}
