//! RV32I interpreter: the SoC control CPU (paper ref. [41] — "a RISC-V CPU
//! that controls the SoC").  Base integer ISA (no CSR/FENCE semantics
//! beyond no-ops), byte-addressable RAM, and an MMIO hook for the CAM
//! device bus.

/// Outcome of one executed instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    /// Continue at the (already updated) PC.
    Continue,
    /// ECALL executed: firmware requests a service / halt (a7 = code).
    Ecall,
    /// EBREAK executed.
    Ebreak,
}

/// Execution fault.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Fault {
    BadInstruction { pc: u32, word: u32 },
    BadAccess { pc: u32, addr: u32 },
    StepLimit,
}

/// A memory-mapped device on the bus.
pub trait MmioDevice {
    /// Word read at device-relative offset (must be 4-aligned).
    fn read(&mut self, offset: u32) -> u32;
    /// Word write at device-relative offset.
    fn write(&mut self, offset: u32, value: u32);
}

/// Bus layout: RAM at 0, one MMIO window.
pub const MMIO_BASE: u32 = 0x4000_0000;
pub const MMIO_SIZE: u32 = 0x1000;

/// The RV32I hart + memory.
pub struct Cpu<'d> {
    pub regs: [u32; 32],
    pub pc: u32,
    pub ram: Vec<u8>,
    pub device: Option<&'d mut dyn MmioDevice>,
    pub instret: u64,
}

impl<'d> Cpu<'d> {
    pub fn new(ram_bytes: usize) -> Self {
        Cpu {
            regs: [0; 32],
            pc: 0,
            ram: vec![0; ram_bytes],
            device: None,
            instret: 0,
        }
    }

    pub fn with_device(ram_bytes: usize, device: &'d mut dyn MmioDevice) -> Self {
        let mut cpu = Cpu::new(ram_bytes);
        cpu.device = Some(device);
        cpu
    }

    /// Load a program image at `addr`.
    pub fn load(&mut self, addr: u32, image: &[u8]) {
        self.ram[addr as usize..addr as usize + image.len()].copy_from_slice(image);
    }

    #[inline]
    fn reg(&self, r: u32) -> u32 {
        self.regs[r as usize]
    }

    #[inline]
    fn set_reg(&mut self, r: u32, v: u32) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
    }

    fn load_word(&mut self, addr: u32, pc: u32) -> Result<u32, Fault> {
        if addr >= MMIO_BASE && addr < MMIO_BASE + MMIO_SIZE {
            let dev = self.device.as_mut().ok_or(Fault::BadAccess { pc, addr })?;
            return Ok(dev.read(addr - MMIO_BASE));
        }
        let a = addr as usize;
        if a + 4 > self.ram.len() {
            return Err(Fault::BadAccess { pc, addr });
        }
        Ok(u32::from_le_bytes(self.ram[a..a + 4].try_into().unwrap()))
    }

    fn store_word(&mut self, addr: u32, v: u32, pc: u32) -> Result<(), Fault> {
        if addr >= MMIO_BASE && addr < MMIO_BASE + MMIO_SIZE {
            let dev = self.device.as_mut().ok_or(Fault::BadAccess { pc, addr })?;
            dev.write(addr - MMIO_BASE, v);
            return Ok(());
        }
        let a = addr as usize;
        if a + 4 > self.ram.len() {
            return Err(Fault::BadAccess { pc, addr });
        }
        self.ram[a..a + 4].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    fn load_byte(&mut self, addr: u32, pc: u32) -> Result<u8, Fault> {
        if addr >= MMIO_BASE {
            // byte access to MMIO: read the word and slice
            let w = self.load_word(addr & !3, pc)?;
            return Ok((w >> ((addr % 4) * 8)) as u8);
        }
        self.ram
            .get(addr as usize)
            .copied()
            .ok_or(Fault::BadAccess { pc, addr })
    }

    fn store_byte(&mut self, addr: u32, v: u8, pc: u32) -> Result<(), Fault> {
        if addr >= MMIO_BASE {
            return Err(Fault::BadAccess { pc, addr }); // word-only MMIO writes
        }
        match self.ram.get_mut(addr as usize) {
            Some(slot) => {
                *slot = v;
                Ok(())
            }
            None => Err(Fault::BadAccess { pc, addr }),
        }
    }

    /// Execute one instruction.
    pub fn step(&mut self) -> Result<Step, Fault> {
        let pc = self.pc;
        let word = self.load_word(pc, pc)?;
        self.instret += 1;
        let opcode = word & 0x7f;
        let rd = (word >> 7) & 0x1f;
        let rs1 = (word >> 15) & 0x1f;
        let rs2 = (word >> 20) & 0x1f;
        let funct3 = (word >> 12) & 7;
        let funct7 = word >> 25;
        let imm_i = (word as i32) >> 20;
        let imm_s = (((word & 0xfe00_0000) as i32) >> 20) | (((word >> 7) & 0x1f) as i32);
        let imm_b = ((((word >> 31) & 1) << 12)
            | (((word >> 7) & 1) << 11)
            | (((word >> 25) & 0x3f) << 5)
            | (((word >> 8) & 0xf) << 1)) as i32;
        let imm_b = (imm_b << 19) >> 19; // sign-extend 13-bit
        let imm_u = (word & 0xffff_f000) as i32;
        let imm_j = ((((word >> 31) & 1) << 20)
            | (((word >> 12) & 0xff) << 12)
            | (((word >> 20) & 1) << 11)
            | (((word >> 21) & 0x3ff) << 1)) as i32;
        let imm_j = (imm_j << 11) >> 11; // sign-extend 21-bit

        let mut next_pc = pc.wrapping_add(4);
        match opcode {
            0x37 => self.set_reg(rd, imm_u as u32), // LUI
            0x17 => self.set_reg(rd, pc.wrapping_add(imm_u as u32)), // AUIPC
            0x6f => {
                // JAL
                self.set_reg(rd, next_pc);
                next_pc = pc.wrapping_add(imm_j as u32);
            }
            0x67 => {
                // JALR
                let t = self.reg(rs1).wrapping_add(imm_i as u32) & !1;
                self.set_reg(rd, next_pc);
                next_pc = t;
            }
            0x63 => {
                // branches
                let a = self.reg(rs1);
                let b = self.reg(rs2);
                let take = match funct3 {
                    0 => a == b,
                    1 => a != b,
                    4 => (a as i32) < (b as i32),
                    5 => (a as i32) >= (b as i32),
                    6 => a < b,
                    7 => a >= b,
                    _ => return Err(Fault::BadInstruction { pc, word }),
                };
                if take {
                    next_pc = pc.wrapping_add(imm_b as u32);
                }
            }
            0x03 => {
                // loads
                let addr = self.reg(rs1).wrapping_add(imm_i as u32);
                let v = match funct3 {
                    0 => self.load_byte(addr, pc)? as i8 as i32 as u32,
                    1 => {
                        let lo = self.load_byte(addr, pc)? as u32;
                        let hi = self.load_byte(addr + 1, pc)? as u32;
                        ((lo | (hi << 8)) as u16) as i16 as i32 as u32
                    }
                    2 => self.load_word(addr, pc)?,
                    4 => self.load_byte(addr, pc)? as u32,
                    5 => {
                        let lo = self.load_byte(addr, pc)? as u32;
                        let hi = self.load_byte(addr + 1, pc)? as u32;
                        lo | (hi << 8)
                    }
                    _ => return Err(Fault::BadInstruction { pc, word }),
                };
                self.set_reg(rd, v);
            }
            0x23 => {
                // stores
                let addr = self.reg(rs1).wrapping_add(imm_s as u32);
                let v = self.reg(rs2);
                match funct3 {
                    0 => self.store_byte(addr, v as u8, pc)?,
                    1 => {
                        self.store_byte(addr, v as u8, pc)?;
                        self.store_byte(addr + 1, (v >> 8) as u8, pc)?;
                    }
                    2 => self.store_word(addr, v, pc)?,
                    _ => return Err(Fault::BadInstruction { pc, word }),
                }
            }
            0x13 => {
                // ALU immediate
                let a = self.reg(rs1);
                let v = match funct3 {
                    0 => a.wrapping_add(imm_i as u32),
                    2 => ((a as i32) < imm_i) as u32,
                    3 => (a < imm_i as u32) as u32,
                    4 => a ^ imm_i as u32,
                    6 => a | imm_i as u32,
                    7 => a & imm_i as u32,
                    1 => a.wrapping_shl(rs2),
                    5 => {
                        if funct7 & 0x20 != 0 {
                            ((a as i32) >> rs2) as u32
                        } else {
                            a.wrapping_shr(rs2)
                        }
                    }
                    _ => return Err(Fault::BadInstruction { pc, word }),
                };
                self.set_reg(rd, v);
            }
            0x33 => {
                // ALU register
                let a = self.reg(rs1);
                let b = self.reg(rs2);
                let v = match (funct3, funct7) {
                    (0, 0x00) => a.wrapping_add(b),
                    (0, 0x20) => a.wrapping_sub(b),
                    (1, 0x00) => a.wrapping_shl(b & 31),
                    (2, 0x00) => ((a as i32) < (b as i32)) as u32,
                    (3, 0x00) => (a < b) as u32,
                    (4, 0x00) => a ^ b,
                    (5, 0x00) => a.wrapping_shr(b & 31),
                    (5, 0x20) => ((a as i32) >> (b & 31)) as u32,
                    (6, 0x00) => a | b,
                    (7, 0x00) => a & b,
                    _ => return Err(Fault::BadInstruction { pc, word }),
                };
                self.set_reg(rd, v);
            }
            0x0f => {} // FENCE: no-op
            0x73 => {
                self.pc = next_pc;
                return Ok(if imm_i == 1 { Step::Ebreak } else { Step::Ecall });
            }
            _ => return Err(Fault::BadInstruction { pc, word }),
        }
        self.pc = next_pc;
        Ok(Step::Continue)
    }

    /// Run until ECALL/EBREAK or the step limit; returns instruction count.
    pub fn run(&mut self, max_steps: u64) -> Result<u64, Fault> {
        let start = self.instret;
        loop {
            match self.step()? {
                Step::Continue => {
                    if self.instret - start >= max_steps {
                        return Err(Fault::StepLimit);
                    }
                }
                Step::Ecall | Step::Ebreak => return Ok(self.instret - start),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::riscv::asm::assemble;

    fn run_asm(src: &str) -> Cpu<'static> {
        let image = assemble(src).expect("assemble");
        let mut cpu = Cpu::new(64 * 1024);
        cpu.load(0, &image);
        cpu.run(100_000).expect("run");
        cpu
    }

    #[test]
    fn arithmetic_and_logic() {
        let cpu = run_asm(
            "li a0, 20\n\
             li a1, 22\n\
             add a2, a0, a1\n\
             sub a3, a0, a1\n\
             xor a4, a0, a1\n\
             and a5, a0, a1\n\
             or a6, a0, a1\n\
             ecall\n",
        );
        assert_eq!(cpu.regs[12], 42); // a2
        assert_eq!(cpu.regs[13] as i32, -2); // a3
        assert_eq!(cpu.regs[14], 20 ^ 22);
        assert_eq!(cpu.regs[15], 20 & 22);
        assert_eq!(cpu.regs[16], 20 | 22);
    }

    #[test]
    fn shifts_and_compares() {
        let cpu = run_asm(
            "li a0, -8\n\
             srai a1, a0, 1\n\
             srli a2, a0, 1\n\
             slli a3, a0, 1\n\
             slti a4, a0, 0\n\
             sltiu a5, a0, 0\n\
             ecall\n",
        );
        assert_eq!(cpu.regs[11] as i32, -4);
        assert_eq!(cpu.regs[12], (-8i32 as u32) >> 1);
        assert_eq!(cpu.regs[13] as i32, -16);
        assert_eq!(cpu.regs[14], 1);
        assert_eq!(cpu.regs[15], 0);
    }

    #[test]
    fn loads_stores_all_widths() {
        let cpu = run_asm(
            "li a0, 0x1000\n\
             li a1, 0x12345678\n\
             sw a1, 0(a0)\n\
             lw a2, 0(a0)\n\
             lh a3, 0(a0)\n\
             lhu a4, 2(a0)\n\
             lb a5, 3(a0)\n\
             lbu a6, 1(a0)\n\
             ecall\n",
        );
        assert_eq!(cpu.regs[12], 0x1234_5678);
        assert_eq!(cpu.regs[13], 0x5678);
        assert_eq!(cpu.regs[14], 0x1234);
        assert_eq!(cpu.regs[15], 0x12);
        assert_eq!(cpu.regs[16], 0x56);
    }

    #[test]
    fn branch_loop_sums() {
        // sum 1..=10 with a bne loop
        let cpu = run_asm(
            "li a0, 0\n\
             li a1, 1\n\
             li a2, 11\n\
             loop:\n\
             add a0, a0, a1\n\
             addi a1, a1, 1\n\
             bne a1, a2, loop\n\
             ecall\n",
        );
        assert_eq!(cpu.regs[10], 55);
    }

    #[test]
    fn jal_and_jalr_call_return() {
        let cpu = run_asm(
            "li a0, 5\n\
             call double\n\
             call double\n\
             ecall\n\
             double:\n\
             add a0, a0, a0\n\
             ret\n",
        );
        assert_eq!(cpu.regs[10], 20);
    }

    #[test]
    fn x0_is_hardwired_zero() {
        let cpu = run_asm("li x0, 99\nli a0, 7\nadd a0, a0, x0\necall\n");
        assert_eq!(cpu.regs[0], 0);
        assert_eq!(cpu.regs[10], 7);
    }

    #[test]
    fn bad_instruction_faults() {
        let mut cpu = Cpu::new(4096);
        cpu.load(0, &0xffff_ffffu32.to_le_bytes());
        assert!(matches!(cpu.step(), Err(Fault::BadInstruction { .. })));
    }

    #[test]
    fn step_limit_enforced() {
        // infinite loop: j 0
        let image = assemble("loop: j loop\n").unwrap();
        let mut cpu = Cpu::new(4096);
        cpu.load(0, &image);
        assert_eq!(cpu.run(1000), Err(Fault::StepLimit));
    }
}
