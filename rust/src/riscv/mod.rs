//! RISC-V control plane: the RV32I CPU + memory-mapped CAM bus + control
//! firmware that together model the paper's SoC ([41] — "LEO-II" research
//! platform: PiC-BNN plus a RISC-V CPU that controls the SoC).

pub mod asm;
pub mod cpu;
pub mod firmware;
pub mod mmio;

pub use asm::assemble;
pub use cpu::{Cpu, Fault, MmioDevice, Step};
pub use mmio::CamMmio;
