//! Per-lane service metrics: latency distribution, batch shaping, and
//! admission outcomes (admitted vs shed).
//!
//! One [`ServerMetrics`] per tenant lane.  Latency percentiles come from
//! a bounded deterministic reservoir (`util::stats::Summary`), so a
//! long-running lane's memory stays constant; `p999` needs a tail, so the
//! serving bench sizes its reservoir generously but the default cap is
//! already exact below 4096 samples.

use crate::accel::ScrubStats;
use crate::cam::DegradedMode;
use crate::util::stats::Summary;

/// Aggregate service metrics for one lane.
#[derive(Clone, Debug, Default)]
pub struct ServerMetrics {
    /// Responses produced (completions).
    pub served: u64,
    /// Device batches executed.
    pub batches: u64,
    /// Requests accepted into the lane's queue.
    pub admitted: u64,
    /// Requests rejected at admission with a typed reason — the lane's
    /// shed load (`server::Rejected` carries the reason to the caller).
    pub shed: u64,
    /// Live-migration steps the engine's maintenance hook applied to
    /// this lane's pool (at most one per scheduler tick).
    pub migration_steps: u64,
    /// Programming cycles (row writes) those migration steps spent.
    pub migration_cycles: u64,
    /// Predicted steady-state retunes/batch saved by the migrations the
    /// re-planning controller started on this lane (the cost model's
    /// claim — never counted before the controller commits a plan).
    pub migration_retunes_saved: u64,
    /// Rows read-verified by the scrub maintenance task on this lane's
    /// pool (amortised a few rows per inter-batch gap).
    pub scrubbed_rows: u64,
    /// Faults the scrubber detected (read-verify mismatches, canary
    /// failures, rail drift/stuck conditions).
    pub faults_detected: u64,
    /// In-place repairs (rewrites, spare-row remaps, rail re-trims).
    pub faults_repaired: u64,
    /// Whole-copy rebuilds after in-place repair failed.
    pub replica_rebuilds: u64,
    /// Replicas quarantined after exhausting their rebuild budget.
    pub replica_quarantines: u64,
    /// Faults past every recovery rung (the lane refuses rather than
    /// serve silently wrong answers).
    pub unrepairable: u64,
    /// Clean canary laps credited to macros on probation (operator
    /// re-admitted, not yet load-bearing).
    pub probation_laps: u64,
    /// Probation macros that passed their canary gate and rejoined
    /// serving as live replicas.
    pub readmissions: u64,
    /// Probations that failed a canary and were re-quarantined (with the
    /// lap requirement doubled — see `cam::faults`).
    pub probation_failures: u64,
    /// Health of the lane's pool as of the last scrub maintenance turn.
    /// Degradation is monotone per fault (`Nominal` → `Failover` →
    /// `Refusing`); the one path back to `Nominal` is a re-admission
    /// that clears the last quarantined macro.
    pub degraded: DegradedMode,
    pub latency_ms: Summary,
    pub batch_sizes: Summary,
}

impl ServerMetrics {
    /// Fold one scrub-maintenance turn's delta into the lane counters
    /// (the engine calls this from its maintenance hook).
    pub fn add_scrub(&mut self, delta: &ScrubStats) {
        self.scrubbed_rows += delta.rows_scrubbed;
        self.faults_detected += delta.faults_detected;
        self.faults_repaired += delta.repairs;
        self.replica_rebuilds += delta.rebuilds;
        self.replica_quarantines += delta.quarantines;
        self.unrepairable += delta.unrepairable;
        self.probation_laps += delta.probation_laps;
        self.readmissions += delta.readmissions;
        self.probation_failures += delta.probation_failures;
    }
    /// Median latency [ms].  `NaN` until a request has been served — an
    /// idle server has no latency sample, and `Summary::percentile`
    /// documents the `NaN` sentinel rather than panicking; report
    /// printers should show a placeholder (see `examples/serve.rs`).
    pub fn p50_ms(&self) -> f64 {
        self.latency_ms.percentile(50.0)
    }

    /// 99th-percentile latency [ms]; `NaN` until a request has been
    /// served (see [`Self::p50_ms`]).
    pub fn p99_ms(&self) -> f64 {
        self.latency_ms.percentile(99.0)
    }

    /// 99.9th-percentile latency [ms]; `NaN` until a request has been
    /// served.  Tail fidelity is bounded by the reservoir — exact below
    /// its capacity, an estimate beyond.
    pub fn p999_ms(&self) -> f64 {
        self.latency_ms.percentile(99.9)
    }

    pub fn mean_batch(&self) -> f64 {
        self.batch_sizes.mean()
    }

    /// Fraction of offered load rejected at admission (0.0 when nothing
    /// was offered).
    pub fn shed_rate(&self) -> f64 {
        let offered = self.admitted + self.shed;
        if offered == 0 {
            0.0
        } else {
            self.shed as f64 / offered as f64
        }
    }

    /// Completions per second of the given observation window.
    pub fn goodput(&self, window_s: f64) -> f64 {
        if window_s > 0.0 {
            self.served as f64 / window_s
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_metrics_report_sentinels_not_panics() {
        let m = ServerMetrics::default();
        assert!(m.p50_ms().is_nan());
        assert!(m.p99_ms().is_nan());
        assert!(m.p999_ms().is_nan());
        assert!(m.mean_batch().is_nan());
        assert_eq!(m.shed_rate(), 0.0);
        assert_eq!(m.goodput(1.0), 0.0);
    }

    #[test]
    fn shed_rate_and_goodput_arithmetic() {
        let mut m = ServerMetrics::default();
        m.admitted = 75;
        m.shed = 25;
        m.served = 60;
        assert!((m.shed_rate() - 0.25).abs() < 1e-12);
        assert!((m.goodput(2.0) - 30.0).abs() < 1e-12);
        assert_eq!(m.goodput(0.0), 0.0);
    }

    #[test]
    fn percentiles_cover_the_tail() {
        let mut m = ServerMetrics::default();
        for i in 0..1000 {
            m.latency_ms.push(i as f64);
        }
        assert!((m.p50_ms() - 499.5).abs() < 1.0);
        assert!(m.p999_ms() > m.p99_ms());
        assert!(m.p999_ms() <= 999.0);
    }
}
