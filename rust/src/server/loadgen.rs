//! Deterministic open-loop workload generation: arrival processes for
//! serving studies where the offered load must not depend on how fast
//! the server drains it (open loop — requests arrive on the process's
//! schedule, never paced by completions, so overload is representable).
//!
//! Three arrival processes cover the serving bench's regimes:
//!
//! * [`ArrivalProcess::Poisson`] — homogeneous rate λ (memoryless
//!   steady-state traffic).
//! * [`ArrivalProcess::Bursty`] — a square wave between a base and a
//!   burst rate (duty-cycled overload: the shape that exposes shedding
//!   and deadline behaviour).
//! * [`ArrivalProcess::Diurnal`] — a sinusoid between trough and peak
//!   over a configurable "day" (the million-user aggregate: many
//!   independent users whose activity follows the sun).
//!
//! Non-homogeneous processes are sampled by Lewis–Shedler thinning over
//! the deterministic [`Rng`]: candidates arrive at the peak rate and are
//! kept with probability `rate(t) / peak`.  Same seed → same arrival
//! times, same synthetic user ids, same tenant tags — a [`Workload`] is
//! a replayable trace, which the simulated-clock engine turns into fully
//! reproducible latency distributions.

use std::time::Duration;

use crate::server::clock::Timestamp;
use crate::util::rng::Rng;

/// One synthetic request arrival.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Arrival {
    /// Offset from the workload epoch (feed to `Clock::advance_to`).
    pub at: Timestamp,
    /// Synthetic user id in `[0, n_users)` — the generator draws from a
    /// population of (up to) millions of users per the serving target.
    pub user: u64,
    /// Tenant lane the request targets.
    pub tenant: usize,
}

/// Offered-load shape; rates are arrivals/second (module docs).
#[derive(Clone, Debug)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson at `rate`.
    Poisson { rate: f64 },
    /// Square wave: `burst` for the first `duty` fraction of each
    /// `period`, `base` otherwise.
    Bursty {
        base: f64,
        burst: f64,
        period: Duration,
        duty: f64,
    },
    /// Sinusoid from `trough` (at the epoch) up to `peak` and back over
    /// each `day`.
    Diurnal {
        trough: f64,
        peak: f64,
        day: Duration,
    },
}

impl ArrivalProcess {
    /// Instantaneous arrival rate at `t` [1/s].
    pub fn rate_at(&self, t: Timestamp) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Bursty {
                base,
                burst,
                period,
                duty,
            } => {
                let phase = (t.as_secs_f64() % period.as_secs_f64()) / period.as_secs_f64();
                if phase < *duty {
                    *burst
                } else {
                    *base
                }
            }
            ArrivalProcess::Diurnal { trough, peak, day } => {
                let phase = t.as_secs_f64() / day.as_secs_f64() * std::f64::consts::TAU;
                let mid = (peak + trough) / 2.0;
                let amp = (peak - trough) / 2.0;
                mid - amp * phase.cos()
            }
        }
    }

    /// The process's maximum rate (the thinning envelope).
    pub fn peak_rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Bursty { base, burst, .. } => base.max(*burst),
            ArrivalProcess::Diurnal { trough, peak, .. } => trough.max(*peak),
        }
    }
}

/// A replayable open-loop arrival trace.
#[derive(Clone, Debug, Default)]
pub struct Workload {
    pub arrivals: Vec<Arrival>,
}

impl Workload {
    /// Sample `process` over `[0, horizon)` by Lewis–Shedler thinning.
    /// Each kept arrival draws a user from a population of `n_users` and
    /// a tenant from `tenant_weights` (empty = everything on tenant 0).
    /// Deterministic in `seed`.
    pub fn generate(
        process: &ArrivalProcess,
        horizon: Duration,
        n_users: u64,
        tenant_weights: &[f64],
        seed: u64,
    ) -> Self {
        let peak = process.peak_rate();
        assert!(peak > 0.0, "arrival process must offer load");
        assert!(n_users > 0, "need at least one synthetic user");
        let total_weight: f64 = tenant_weights.iter().sum();
        assert!(
            tenant_weights.is_empty() || total_weight > 0.0,
            "tenant weights must not all be zero"
        );
        let mut rng = Rng::new(seed, 0x10AD_6E4E);
        let horizon_s = horizon.as_secs_f64();
        let mut arrivals = Vec::with_capacity((peak * horizon_s) as usize);
        let mut t = 0.0f64;
        loop {
            // exponential inter-arrival at the envelope rate; 1 - f64()
            // is in (0, 1] so the log is finite
            t += -(1.0 - rng.f64()).ln() / peak;
            if t >= horizon_s {
                break;
            }
            // thinning: keep with probability rate(t) / peak
            if rng.f64() * peak >= process.rate_at(Duration::from_secs_f64(t)) {
                continue;
            }
            let user = rng.below(n_users);
            let tenant = if tenant_weights.is_empty() {
                0
            } else {
                let mut pick = rng.f64() * total_weight;
                let mut chosen = tenant_weights.len() - 1;
                for (i, w) in tenant_weights.iter().enumerate() {
                    pick -= w;
                    if pick < 0.0 {
                        chosen = i;
                        break;
                    }
                }
                chosen
            };
            arrivals.push(Arrival {
                at: Duration::from_secs_f64(t),
                user,
                tenant,
            });
        }
        Workload { arrivals }
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Mean offered rate over the trace's horizon [1/s].
    pub fn offered_rate(&self, horizon: Duration) -> f64 {
        if horizon.is_zero() {
            0.0
        } else {
            self.arrivals.len() as f64 / horizon.as_secs_f64()
        }
    }

    /// Count of arrivals in `[from, to)` — burst/lull inspection.
    pub fn arrivals_between(&self, from: Timestamp, to: Timestamp) -> usize {
        self.arrivals
            .iter()
            .filter(|a| a.at >= from && a.at < to)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn secs(s: u64) -> Duration {
        Duration::from_secs(s)
    }

    #[test]
    fn same_seed_same_trace() {
        let p = ArrivalProcess::Poisson { rate: 500.0 };
        let a = Workload::generate(&p, secs(2), 1_000_000, &[0.5, 0.5], 42);
        let b = Workload::generate(&p, secs(2), 1_000_000, &[0.5, 0.5], 42);
        assert!(!a.is_empty());
        assert_eq!(a.arrivals, b.arrivals, "trace must replay bit-exactly");
        let c = Workload::generate(&p, secs(2), 1_000_000, &[0.5, 0.5], 43);
        assert_ne!(a.arrivals, c.arrivals, "seed must matter");
    }

    #[test]
    fn poisson_rate_is_respected() {
        let p = ArrivalProcess::Poisson { rate: 1000.0 };
        let w = Workload::generate(&p, secs(10), 1_000_000, &[], 7);
        let rate = w.offered_rate(secs(10));
        assert!((rate - 1000.0).abs() < 50.0, "offered {rate}/s vs nominal 1000/s");
        assert!(w.arrivals.windows(2).all(|ab| ab[0].at <= ab[1].at));
    }

    #[test]
    fn bursty_concentrates_arrivals_in_the_burst_window() {
        let p = ArrivalProcess::Bursty {
            base: 100.0,
            burst: 2000.0,
            period: secs(1),
            duty: 0.25,
        };
        let w = Workload::generate(&p, secs(8), 1_000_000, &[], 11);
        let mut in_burst = 0usize;
        let mut in_base = 0usize;
        for a in &w.arrivals {
            let phase = a.at.as_secs_f64() % 1.0;
            if phase < 0.25 {
                in_burst += 1;
            } else {
                in_base += 1;
            }
        }
        // burst window offers 2000 × 0.25 = 500/s of period vs 75/s in
        // the base window: the burst must dominate by a wide margin
        assert!(in_burst > 4 * in_base, "burst {in_burst} vs base {in_base}");
    }

    #[test]
    fn diurnal_peak_beats_trough() {
        let p = ArrivalProcess::Diurnal {
            trough: 50.0,
            peak: 1500.0,
            day: secs(10),
        };
        let w = Workload::generate(&p, secs(10), 3_000_000, &[], 13);
        // trough at the epoch (and again at t=10), peak mid-day
        let around_trough =
            w.arrivals_between(Duration::ZERO, secs(2)) + w.arrivals_between(secs(8), secs(10));
        let around_peak = w.arrivals_between(secs(4), secs(6));
        assert!(
            around_peak > around_trough,
            "peak window {around_peak} vs trough windows {around_trough}"
        );
        // the population is actually millions-scale: ids spread widely
        let max_user = w.arrivals.iter().map(|a| a.user).max().unwrap();
        assert!(max_user > 1_000_000, "user ids confined to {max_user}");
    }

    #[test]
    fn tenant_weights_split_the_trace() {
        let p = ArrivalProcess::Poisson { rate: 2000.0 };
        let w = Workload::generate(&p, secs(5), 1_000_000, &[3.0, 1.0], 17);
        let t0 = w.arrivals.iter().filter(|a| a.tenant == 0).count();
        let t1 = w.len() - t0;
        assert!(t1 > 0, "minority tenant must still see traffic");
        let share = t0 as f64 / w.len() as f64;
        assert!((share - 0.75).abs() < 0.05, "tenant 0 share {share} vs nominal 0.75");
    }
}
