//! In-process inference server: a request/response loop over channels with
//! a dynamic batcher in front of the pipeline — the shape a deployment
//! would put around the accelerator (tokio is unavailable offline; std
//! mpsc + threads carry the same architecture).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::accel::{BatchPolicy, Batcher, Pipeline, PipelineOptions};
use crate::bnn::model::MappedModel;
use crate::util::bitops::BitVec;
use crate::util::stats::Summary;

/// A classification response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub prediction: usize,
    pub votes: Vec<u32>,
    pub latency: Duration,
}

/// Aggregate service metrics.
#[derive(Clone, Debug, Default)]
pub struct ServerMetrics {
    pub served: u64,
    pub batches: u64,
    pub latency_ms: Summary,
    pub batch_sizes: Summary,
}

impl ServerMetrics {
    pub fn p50_ms(&self) -> f64 {
        self.latency_ms.percentile(50.0)
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency_ms.percentile(99.0)
    }

    pub fn mean_batch(&self) -> f64 {
        self.batch_sizes.mean()
    }
}

/// Synchronous single-threaded server core: feed requests in, drive the
/// batcher + pipeline, collect responses.  The threaded front-end
/// (`serve_workload`) wraps this with producer threads.
pub struct Server<'m> {
    pipeline: Pipeline<'m>,
    batcher: Batcher,
    pub metrics: ServerMetrics,
}

impl<'m> Server<'m> {
    pub fn new(model: &'m MappedModel, opts: PipelineOptions, policy: BatchPolicy) -> Self {
        Server {
            pipeline: Pipeline::new(model, opts),
            batcher: Batcher::new(policy),
            metrics: ServerMetrics::default(),
        }
    }

    /// Enqueue one request; returns its id.
    pub fn submit(&mut self, image: BitVec) -> u64 {
        self.batcher.push(image)
    }

    /// Flush pending requests if the policy says so (or `force`).
    /// Returns completed responses.
    pub fn poll(&mut self, force: bool) -> Vec<Response> {
        let now = Instant::now();
        if !force && !self.batcher.ready(now) {
            return Vec::new();
        }
        let batch = if force {
            self.batcher.drain_all()
        } else {
            self.batcher.drain_batch()
        };
        if batch.is_empty() {
            return Vec::new();
        }
        let images: Vec<BitVec> = batch.iter().map(|r| r.image.clone()).collect();
        let results = self.pipeline.classify_batch(&images);
        let done = Instant::now();
        self.metrics.batches += 1;
        self.metrics.batch_sizes.push(batch.len() as f64);
        batch
            .into_iter()
            .zip(results)
            .map(|(req, (votes, prediction))| {
                let latency = done.duration_since(req.enqueued);
                self.metrics.served += 1;
                self.metrics.latency_ms.push(latency.as_secs_f64() * 1e3);
                Response {
                    id: req.id,
                    prediction,
                    votes,
                    latency,
                }
            })
            .collect()
    }

    /// Device statistics accumulated so far.
    pub fn take_device_stats(&mut self) -> crate::accel::RunStats {
        self.pipeline.take_stats(self.metrics.served)
    }
}

/// Drive a server with a workload produced by `n_producers` threads, each
/// submitting `per_producer` images with `inter_arrival` spacing.  Returns
/// (responses in completion order, metrics).
pub fn serve_workload(
    model: &MappedModel,
    opts: PipelineOptions,
    policy: BatchPolicy,
    images: &[BitVec],
    n_producers: usize,
    inter_arrival: Duration,
) -> (Vec<Response>, ServerMetrics) {
    let (tx, rx) = mpsc::channel::<BitVec>();
    std::thread::scope(|s| {
        // producers
        let per = images.len().div_ceil(n_producers.max(1));
        for chunk in images.chunks(per) {
            let tx = tx.clone();
            s.spawn(move || {
                for img in chunk {
                    if tx.send(img.clone()).is_err() {
                        return;
                    }
                    if !inter_arrival.is_zero() {
                        std::thread::sleep(inter_arrival);
                    }
                }
            });
        }
        drop(tx);
        // consumer: the server loop
        let mut server = Server::new(model, opts, policy);
        let mut responses = Vec::with_capacity(images.len());
        loop {
            match rx.recv_timeout(Duration::from_micros(200)) {
                Ok(img) => {
                    server.submit(img);
                    responses.extend(server.poll(false));
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    responses.extend(server.poll(false));
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    responses.extend(server.poll(true));
                    break;
                }
            }
        }
        let metrics = server.metrics.clone();
        (responses, metrics)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::cam::NoiseMode;
    use crate::util::rng::Rng;

    fn images(n: usize, bits: usize) -> Vec<BitVec> {
        let mut rng = Rng::new(8, 8);
        (0..n)
            .map(|_| {
                let mut v = BitVec::zeros(bits);
                for i in 0..bits {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect()
    }

    fn opts() -> PipelineOptions {
        PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        }
    }

    #[test]
    fn serves_all_requests_once() {
        let model = tiny_model(64, 8, 3, 31);
        let imgs = images(40, 64);
        let (responses, metrics) = serve_workload(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
            },
            &imgs,
            3,
            Duration::ZERO,
        );
        assert_eq!(responses.len(), 40);
        assert_eq!(metrics.served, 40);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 40, "every id exactly once");
        assert!(metrics.mean_batch() >= 1.0);
    }

    #[test]
    fn predictions_match_direct_pipeline() {
        let model = tiny_model(64, 8, 3, 32);
        let imgs = images(16, 64);
        let (mut responses, _) = serve_workload(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(50),
            },
            &imgs,
            1,
            Duration::ZERO,
        );
        responses.sort_by_key(|r| r.id);
        let mut pipe = Pipeline::new(&model, opts());
        let want = pipe.classify_batch(&imgs);
        for (r, (votes, pred)) in responses.iter().zip(&want) {
            assert_eq!(&r.prediction, pred);
            assert_eq!(&r.votes, votes);
        }
    }

    #[test]
    fn force_poll_flushes_partial_batch() {
        let model = tiny_model(64, 8, 3, 33);
        let mut server = Server::new(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 100,
                max_wait: Duration::from_secs(60),
            },
        );
        server.submit(images(1, 64).pop().unwrap());
        assert!(server.poll(false).is_empty(), "policy not yet ready");
        let got = server.poll(true);
        assert_eq!(got.len(), 1);
    }
}
