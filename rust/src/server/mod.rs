//! In-process inference server: a request/response loop over channels with
//! a dynamic batcher in front of the resident [`MacroPool`] — the shape a
//! deployment would put around the accelerator (tokio is unavailable
//! offline; std mpsc + threads carry the same architecture).
//!
//! The pool keeps every layer's weights programmed across the server's
//! lifetime, so a served batch never reprograms; under a full macro
//! budget every schedule threshold's rails are also pre-tuned (zero
//! retunes at steady state), and under a degraded budget the placement
//! planner shares output macros between thresholds, paying a bounded,
//! tracked retune cost per batch (see `accel::planner`).  Only models
//! whose hidden loads exceed the budget run on the reload scheduler
//! inside the pool.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::accel::{
    BatchPolicy, Batcher, MacroPool, MultiPool, PipelineOptions, PoolMode, Request, RunStats,
    DEFAULT_POOL_MACROS,
};
use crate::bnn::model::MappedModel;
use crate::util::bitops::BitVec;
use crate::util::stats::Summary;

/// A classification response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Tenant that served the request (0 for single-model servers).  Ids
    /// are unique per tenant lane, so (tenant, id) identifies a request
    /// on a [`MultiServer`].
    pub tenant: usize,
    pub prediction: usize,
    pub votes: Vec<u32>,
    pub latency: Duration,
}

/// Aggregate service metrics.
#[derive(Clone, Debug, Default)]
pub struct ServerMetrics {
    pub served: u64,
    pub batches: u64,
    pub latency_ms: Summary,
    pub batch_sizes: Summary,
}

impl ServerMetrics {
    /// Median latency [ms].  `NaN` until a request has been served — an
    /// idle server has no latency sample, and `Summary::percentile`
    /// documents the `NaN` sentinel rather than panicking; report
    /// printers should show a placeholder (see `examples/serve.rs`).
    pub fn p50_ms(&self) -> f64 {
        self.latency_ms.percentile(50.0)
    }

    /// 99th-percentile latency [ms]; `NaN` until a request has been
    /// served (see [`Self::p50_ms`]).
    pub fn p99_ms(&self) -> f64 {
        self.latency_ms.percentile(99.0)
    }

    pub fn mean_batch(&self) -> f64 {
        self.batch_sizes.mean()
    }
}

/// Synchronous single-threaded server core: feed requests in, drive the
/// batcher + pool, collect responses.  The threaded front-end
/// (`serve_workload`) wraps this with producer threads.
pub struct Server<'m> {
    pool: MacroPool<'m>,
    batcher: Batcher,
    pub metrics: ServerMetrics,
    /// Inferences already reported by `take_device_stats` (delta base).
    stats_reported: u64,
}

impl<'m> Server<'m> {
    pub fn new(model: &'m MappedModel, opts: PipelineOptions, policy: BatchPolicy) -> Self {
        Self::with_capacity(model, opts, policy, DEFAULT_POOL_MACROS)
    }

    /// Server over a pool planned for an explicit macro budget (degraded
    /// budgets keep weights resident and share output macros between
    /// thresholds instead of dropping to the reload scheduler).
    pub fn with_capacity(
        model: &'m MappedModel,
        opts: PipelineOptions,
        policy: BatchPolicy,
        max_macros: usize,
    ) -> Self {
        Server {
            pool: MacroPool::with_capacity(model, opts, max_macros),
            batcher: Batcher::new(policy),
            metrics: ServerMetrics::default(),
            stats_reported: 0,
        }
    }

    /// Execution mode of the backing pool (resident vs reload fallback).
    pub fn pool_mode(&self) -> PoolMode {
        self.pool.mode()
    }

    /// The backing pool (diagnostics: macro count, operating points).
    pub fn pool(&self) -> &MacroPool<'m> {
        &self.pool
    }

    /// Enqueue one request; returns its id.
    pub fn submit(&mut self, image: BitVec) -> u64 {
        self.batcher.push(image)
    }

    /// Flush pending requests as long as the policy says so (or `force`).
    /// Returns completed responses.
    ///
    /// Drains *every* ready batch, not just the first: a burst of several
    /// `max_batch`-fulls clears in one poll.  (The old single-batch drain
    /// left a bursty queue permanently behind the arrival rate — each
    /// poll removed at most one batch while the burst kept the backlog
    /// above the threshold.)
    pub fn poll(&mut self, force: bool) -> Vec<Response> {
        if force {
            let batch = self.batcher.drain_all();
            return self.run_batch(batch);
        }
        let mut responses = Vec::new();
        while self.batcher.ready(Instant::now()) {
            let batch = self.batcher.drain_batch();
            if batch.is_empty() {
                break;
            }
            responses.extend(self.run_batch(batch));
        }
        responses
    }

    /// Classify one drained batch and record its metrics.
    fn run_batch(&mut self, batch: Vec<Request>) -> Vec<Response> {
        if batch.is_empty() {
            return Vec::new();
        }
        // move the images out of the requests — the classify path never
        // clones a request body
        let mut meta = Vec::with_capacity(batch.len());
        let mut images = Vec::with_capacity(batch.len());
        for req in batch {
            meta.push((req.id, req.enqueued));
            images.push(req.image);
        }
        let results = self.pool.classify_batch(&images);
        let done = Instant::now();
        self.metrics.batches += 1;
        self.metrics.batch_sizes.push(images.len() as f64);
        meta.into_iter()
            .zip(results)
            .map(|((id, enqueued), (votes, prediction))| {
                let latency = done.duration_since(enqueued);
                self.metrics.served += 1;
                self.metrics.latency_ms.push(latency.as_secs_f64() * 1e3);
                Response {
                    id,
                    tenant: 0,
                    prediction,
                    votes,
                    latency,
                }
            })
            .collect()
    }

    /// Drain device statistics accumulated since the *previous* call.
    ///
    /// Delta-based: each served inference is attributed to exactly one
    /// report, so calling this twice never double-counts (the pool's
    /// cycle/event counters are drained by `take_stats` and the served
    /// total is diffed against the last report).
    pub fn take_device_stats(&mut self) -> crate::accel::RunStats {
        let delta = self.metrics.served - self.stats_reported;
        self.stats_reported = self.metrics.served;
        self.pool.take_stats(delta)
    }
}

/// Multi-tenant server core: one [`MultiPool`] (one macro budget shared
/// across N models), one batcher lane and one [`ServerMetrics`] per
/// tenant.  Requests are tenant-tagged at submission; lanes batch
/// independently (a device batch is always tenant-homogeneous — tenants
/// are different models) and `poll` drains every lane's ready batches.
pub struct MultiServer<'m> {
    pool: MultiPool<'m>,
    lanes: Vec<Batcher>,
    pub metrics: Vec<ServerMetrics>,
    /// Per-tenant inferences already reported (delta bases).
    stats_reported: Vec<u64>,
}

impl<'m> MultiServer<'m> {
    /// Server over `models` sharing `max_macros` with equal traffic
    /// shares (see [`MultiPool::new`]).
    pub fn new(
        models: &[&'m MappedModel],
        opts: PipelineOptions,
        policy: BatchPolicy,
        max_macros: usize,
    ) -> Self {
        Self::with_shares(models, opts, policy, max_macros, &[])
    }

    /// Server with explicit per-tenant traffic shares: surplus macro
    /// budget follows the shares (see `accel::planner::plan_tenants`);
    /// an empty slice means equal shares.
    pub fn with_shares(
        models: &[&'m MappedModel],
        opts: PipelineOptions,
        policy: BatchPolicy,
        max_macros: usize,
        shares: &[f64],
    ) -> Self {
        let pool = MultiPool::with_shares(models, opts, max_macros, 1, shares);
        let n = pool.n_tenants();
        MultiServer {
            pool,
            lanes: (0..n).map(|_| Batcher::new(policy)).collect(),
            metrics: vec![ServerMetrics::default(); n],
            stats_reported: vec![0; n],
        }
    }

    pub fn n_tenants(&self) -> usize {
        self.lanes.len()
    }

    /// The backing multi-tenant pool (plans, modes, diagnostics).
    pub fn pool(&self) -> &MultiPool<'m> {
        &self.pool
    }

    /// Enqueue one request for `tenant`; returns its id (unique within
    /// the tenant's lane — pair with the tenant for a global key).
    pub fn submit(&mut self, tenant: usize, image: BitVec) -> u64 {
        self.lanes[tenant].push_tagged(tenant, image)
    }

    /// Flush every tenant lane as long as its policy says so (or `force`).
    /// Returns completed responses across all tenants.  Like
    /// [`Server::poll`], each lane drains *every* ready batch per call.
    pub fn poll(&mut self, force: bool) -> Vec<Response> {
        let mut responses = Vec::new();
        for tenant in 0..self.lanes.len() {
            if force {
                let batch = self.lanes[tenant].drain_all();
                responses.extend(self.run_lane(tenant, batch));
                continue;
            }
            while self.lanes[tenant].ready(Instant::now()) {
                let batch = self.lanes[tenant].drain_batch();
                if batch.is_empty() {
                    break;
                }
                responses.extend(self.run_lane(tenant, batch));
            }
        }
        responses
    }

    /// Classify one tenant's drained batch and record its lane metrics.
    fn run_lane(&mut self, tenant: usize, batch: Vec<Request>) -> Vec<Response> {
        if batch.is_empty() {
            return Vec::new();
        }
        let mut meta = Vec::with_capacity(batch.len());
        let mut images = Vec::with_capacity(batch.len());
        for req in batch {
            debug_assert_eq!(req.tenant, tenant, "lane holds one tenant");
            meta.push((req.id, req.enqueued));
            images.push(req.image);
        }
        let results = self.pool.classify_batch(tenant, &images);
        let done = Instant::now();
        let metrics = &mut self.metrics[tenant];
        metrics.batches += 1;
        metrics.batch_sizes.push(images.len() as f64);
        meta.into_iter()
            .zip(results)
            .map(|((id, enqueued), (votes, prediction))| {
                let latency = done.duration_since(enqueued);
                metrics.served += 1;
                metrics.latency_ms.push(latency.as_secs_f64() * 1e3);
                Response {
                    id,
                    tenant,
                    prediction,
                    votes,
                    latency,
                }
            })
            .collect()
    }

    /// Drain one tenant's device statistics accumulated since the
    /// previous call for that tenant (delta-based, like
    /// [`Server::take_device_stats`]).
    pub fn take_device_stats(&mut self, tenant: usize) -> RunStats {
        let delta = self.metrics[tenant].served - self.stats_reported[tenant];
        self.stats_reported[tenant] = self.metrics[tenant].served;
        self.pool.take_stats(tenant, delta)
    }
}

/// Drive a server with a workload produced by `n_producers` threads, each
/// submitting a share of `images` with `inter_arrival` spacing.  Returns
/// (responses in completion order, metrics).
pub fn serve_workload(
    model: &MappedModel,
    opts: PipelineOptions,
    policy: BatchPolicy,
    images: &[BitVec],
    n_producers: usize,
    inter_arrival: Duration,
) -> (Vec<Response>, ServerMetrics) {
    serve_workload_with_capacity(
        model,
        opts,
        policy,
        images,
        n_producers,
        inter_arrival,
        DEFAULT_POOL_MACROS,
    )
}

/// [`serve_workload`] over a pool planned for an explicit macro budget.
#[allow(clippy::too_many_arguments)]
pub fn serve_workload_with_capacity(
    model: &MappedModel,
    opts: PipelineOptions,
    policy: BatchPolicy,
    images: &[BitVec],
    n_producers: usize,
    inter_arrival: Duration,
    max_macros: usize,
) -> (Vec<Response>, ServerMetrics) {
    let (tx, rx) = mpsc::channel::<BitVec>();
    std::thread::scope(|s| {
        // producers
        let per = images.len().div_ceil(n_producers.max(1));
        for chunk in images.chunks(per.max(1)) {
            let tx = tx.clone();
            s.spawn(move || {
                for img in chunk {
                    if tx.send(img.clone()).is_err() {
                        return;
                    }
                    if !inter_arrival.is_zero() {
                        std::thread::sleep(inter_arrival);
                    }
                }
            });
        }
        drop(tx);
        // consumer: the server loop
        let mut server = Server::with_capacity(model, opts, policy, max_macros);
        let mut responses = Vec::with_capacity(images.len());
        loop {
            match rx.recv_timeout(Duration::from_micros(200)) {
                Ok(img) => {
                    server.submit(img);
                    responses.extend(server.poll(false));
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    responses.extend(server.poll(false));
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    responses.extend(server.poll(true));
                    break;
                }
            }
        }
        let metrics = server.metrics.clone();
        (responses, metrics)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Pipeline;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::cam::NoiseMode;
    use crate::util::rng::Rng;

    fn images(n: usize, bits: usize) -> Vec<BitVec> {
        let mut rng = Rng::new(8, 8);
        (0..n)
            .map(|_| {
                let mut v = BitVec::zeros(bits);
                for i in 0..bits {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect()
    }

    fn opts() -> PipelineOptions {
        PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        }
    }

    #[test]
    fn serves_all_requests_once() {
        let model = tiny_model(64, 8, 3, 31);
        let imgs = images(40, 64);
        let (responses, metrics) = serve_workload(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
            },
            &imgs,
            3,
            Duration::ZERO,
        );
        assert_eq!(responses.len(), 40);
        assert_eq!(metrics.served, 40);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 40, "every id exactly once");
        assert!(metrics.mean_batch() >= 1.0);
    }

    #[test]
    fn predictions_match_direct_pipeline() {
        let model = tiny_model(64, 8, 3, 32);
        let imgs = images(16, 64);
        let (mut responses, _) = serve_workload(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(50),
            },
            &imgs,
            1,
            Duration::ZERO,
        );
        responses.sort_by_key(|r| r.id);
        let mut pipe = Pipeline::new(&model, opts());
        let want = pipe.classify_batch(&imgs);
        for (r, (votes, pred)) in responses.iter().zip(&want) {
            assert_eq!(&r.prediction, pred);
            assert_eq!(&r.votes, votes);
        }
    }

    #[test]
    fn force_poll_flushes_partial_batch() {
        let model = tiny_model(64, 8, 3, 33);
        let mut server = Server::new(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 100,
                max_wait: Duration::from_secs(60),
            },
        );
        server.submit(images(1, 64).pop().unwrap());
        assert!(server.poll(false).is_empty(), "policy not yet ready");
        let got = server.poll(true);
        assert_eq!(got.len(), 1);
    }

    #[test]
    fn burst_of_full_batches_clears_in_one_poll() {
        // regression: poll(force=false) used to drain at most one
        // max_batch per call, so a burst left the queue permanently
        // behind the arrival rate
        let model = tiny_model(64, 8, 3, 36);
        let mut server = Server::new(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_secs(60),
            },
        );
        for img in images(3 * 8, 64) {
            server.submit(img);
        }
        let got = server.poll(false);
        assert_eq!(got.len(), 24, "3×max_batch burst must clear in one poll");
        assert_eq!(server.metrics.batches, 3, "drained as policy-sized batches");
        assert!(server.poll(false).is_empty(), "queue actually empty");
    }

    #[test]
    fn poll_drains_timed_out_partial_batch_after_full_ones() {
        let model = tiny_model(64, 8, 3, 37);
        let mut server = Server::new(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO, // everything is instantly due
            },
        );
        for img in images(2 * 8 + 3, 64) {
            server.submit(img);
        }
        let got = server.poll(false);
        assert_eq!(got.len(), 19, "two full batches + the due partial one");
        assert_eq!(server.metrics.batches, 3);
    }

    #[test]
    fn degraded_budget_serves_resident_with_bounded_retunes() {
        // tentpole acceptance at the server layer: a model whose full
        // residency exceeds the budget still serves with zero
        // steady-state programming and a planned, bounded retune cost
        let model = tiny_model(64, 8, 3, 38);
        let required = MacroPool::macros_required(&model, &opts());
        let budget = required / 2;
        let mut server = Server::with_capacity(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO,
            },
            budget,
        );
        assert_eq!(server.pool_mode(), PoolMode::Resident);
        let predicted = server.pool().plan().unwrap().predicted_retunes_per_batch();
        assert!(predicted > 0, "sharing must be active at half budget");
        // warmup epoch
        for img in images(8, 64) {
            server.submit(img);
        }
        server.poll(true);
        server.take_device_stats();
        // steady state: zero programming, retunes bounded by the plan
        for img in images(8, 64) {
            server.submit(img);
        }
        server.poll(true);
        let steady = server.take_device_stats();
        assert_eq!(steady.programming_cycles(), 0);
        assert!(steady.events.retunes > 0);
        assert!(steady.events.retunes <= predicted);
        assert_eq!(steady.hidden_cost.retunes, 0);
        assert_eq!(steady.output_cost.retunes, steady.events.retunes);
        // and the predictions still match the reload pipeline bit-exactly
        let imgs = images(8, 64);
        for img in &imgs {
            server.submit(img.clone());
        }
        let mut responses = server.poll(true);
        responses.sort_by_key(|r| r.id);
        let mut pipe = Pipeline::new(&model, opts());
        let want = pipe.classify_batch(&imgs);
        for (r, (votes, pred)) in responses.iter().zip(&want) {
            assert_eq!(&r.prediction, pred);
            assert_eq!(&r.votes, votes);
        }
    }

    #[test]
    fn device_stats_are_delta_based_not_cumulative() {
        // regression: take_device_stats used to re-report the cumulative
        // served count on every call
        let model = tiny_model(64, 8, 3, 34);
        let mut server = Server::new(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO,
            },
        );
        for img in images(8, 64) {
            server.submit(img);
        }
        assert_eq!(server.poll(true).len(), 8);
        let first = server.take_device_stats();
        assert_eq!(first.inferences, 8);
        assert!(first.cycles > 0);
        // nothing served in between: second report must be empty
        let second = server.take_device_stats();
        assert_eq!(second.inferences, 0, "cumulative double count");
        assert_eq!(second.cycles, 0, "device counters not drained");
        // serve more: only the new inferences appear
        for img in images(5, 64) {
            server.submit(img);
        }
        assert_eq!(server.poll(true).len(), 5);
        let third = server.take_device_stats();
        assert_eq!(third.inferences, 5);
        assert!(third.cycles > 0);
    }

    #[test]
    fn idle_server_reports_nan_percentiles_not_a_panic() {
        // regression guard: percentile over an empty latency reservoir
        // must return the documented NaN sentinel, never index-panic
        let model = tiny_model(64, 8, 3, 39);
        let server = Server::new(&model, opts(), BatchPolicy::default());
        assert!(server.metrics.p50_ms().is_nan());
        assert!(server.metrics.p99_ms().is_nan());
        assert!(server.metrics.mean_batch().is_nan());
        // a multi-tenant server's idle lanes behave the same way
        let b = tiny_model(64, 8, 3, 40);
        let multi = MultiServer::new(&[&model, &b], opts(), BatchPolicy::default(), 16);
        for m in &multi.metrics {
            assert!(m.p50_ms().is_nan());
            assert!(m.p99_ms().is_nan());
        }
    }

    #[test]
    fn multi_server_serves_two_tenants_from_one_budget() {
        // tentpole acceptance at the server layer: one budget, two model
        // shapes, per-tenant metrics, zero steady-state programming, and
        // per-tenant predictions bit-identical to standalone pools
        let a = tiny_model(100, 16, 4, 41);
        let b = tiny_model(64, 8, 3, 42);
        let budget = MacroPool::macros_required(&a, &opts())
            + MacroPool::macros_required(&b, &opts());
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::ZERO,
        };
        let mut server = MultiServer::new(&[&a, &b], opts(), policy, budget);
        assert_eq!(server.n_tenants(), 2);
        assert_eq!(server.pool().tenant(0).mode(), PoolMode::Resident);
        assert_eq!(server.pool().tenant(1).mode(), PoolMode::Resident);
        let imgs_a = images(8, 100);
        let imgs_b = images(8, 64);
        // warmup epoch: interleaved tenant submissions
        for (ia, ib) in imgs_a.iter().zip(&imgs_b) {
            server.submit(0, ia.clone());
            server.submit(1, ib.clone());
        }
        server.poll(true);
        server.take_device_stats(0);
        server.take_device_stats(1);
        // steady state: both tenants pay zero programming and zero retunes
        for (ia, ib) in imgs_a.iter().zip(&imgs_b) {
            server.submit(0, ia.clone());
            server.submit(1, ib.clone());
        }
        let mut responses = server.poll(true);
        for t in 0..2 {
            let steady = server.take_device_stats(t);
            assert_eq!(steady.inferences, 8, "tenant {t}");
            assert_eq!(steady.programming_cycles(), 0, "tenant {t}");
            assert_eq!(steady.events.retunes, 0, "tenant {t}");
            assert_eq!(server.metrics[t].served, 16, "tenant {t}");
        }
        // per-tenant predictions match the reload pipelines bit-exactly
        responses.sort_by_key(|r| (r.tenant, r.id));
        let (ra, rb): (Vec<_>, Vec<_>) = responses.into_iter().partition(|r| r.tenant == 0);
        let mut pipe_a = Pipeline::new(&a, opts());
        let mut pipe_b = Pipeline::new(&b, opts());
        // the steady-state epoch re-served the same images
        let want_a = pipe_a.classify_batch(&imgs_a);
        let want_b = pipe_b.classify_batch(&imgs_b);
        for (r, (votes, pred)) in ra.iter().zip(&want_a) {
            assert_eq!(&r.prediction, pred);
            assert_eq!(&r.votes, votes);
        }
        for (r, (votes, pred)) in rb.iter().zip(&want_b) {
            assert_eq!(&r.prediction, pred);
            assert_eq!(&r.votes, votes);
        }
    }

    #[test]
    fn multi_server_partial_batches_flush_per_lane() {
        let a = tiny_model(64, 8, 3, 43);
        let b = tiny_model(64, 8, 3, 44);
        let mut server = MultiServer::new(
            &[&a, &b],
            opts(),
            BatchPolicy {
                max_batch: 100,
                max_wait: Duration::from_secs(60),
            },
            16,
        );
        server.submit(0, images(1, 64).pop().unwrap());
        server.submit(1, images(1, 64).pop().unwrap());
        assert!(server.poll(false).is_empty(), "policies not yet ready");
        let got = server.poll(true);
        assert_eq!(got.len(), 2);
        let tenants: Vec<usize> = got.iter().map(|r| r.tenant).collect();
        assert!(tenants.contains(&0) && tenants.contains(&1));
        assert_eq!(server.metrics[0].served, 1);
        assert_eq!(server.metrics[1].served, 1);
    }

    #[test]
    fn server_runs_resident_and_pays_no_steady_state_programming() {
        let model = tiny_model(64, 8, 3, 35);
        let mut server = Server::new(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO,
            },
        );
        assert_eq!(server.pool_mode(), PoolMode::Resident);
        // warmup epoch: construction programming drains with the first take
        for img in images(8, 64) {
            server.submit(img);
        }
        server.poll(true);
        server.take_device_stats();
        // steady state: zero programming / retunes
        for img in images(8, 64) {
            server.submit(img);
        }
        server.poll(true);
        let steady = server.take_device_stats();
        assert_eq!(steady.programming_cycles(), 0);
        assert_eq!(steady.events.retunes, 0);
        assert!(steady.events.searches > 0);
    }
}
