//! In-process inference serving stack, staged as
//! **ingress → lane → executor** (tokio is unavailable offline; std mpsc
//! + scoped threads carry the same architecture):
//!
//! * [`clock`] — the time seam: wall vs deterministic simulated time.
//!   Every scheduling decision reads a [`Clock`]; no raw `Instant::now()`
//!   survives in the serving stack.
//! * [`engine`] — the unified core: bounded-MPSC ingress, per-tenant
//!   batcher lanes with half-budget deadline closing, QoS-aware
//!   admission with typed [`Rejected`] backpressure, and the executor
//!   that drains ready batches into the resident pool.
//! * [`metrics`] — per-lane latency/goodput/shed accounting.
//! * [`loadgen`] — deterministic open-loop arrival processes (Poisson,
//!   bursty, diurnal) for overload studies and `benches/serving.rs`.
//!
//! [`Server`] and [`MultiServer`] are thin facades over one [`Engine`]:
//! same pool residency guarantees as before (weights stay programmed for
//! the server's lifetime; degraded budgets share output macros with a
//! planned retune bound — see `accel::planner`), same delta-based device
//! stats, but one implementation of the poll loop instead of two.  The
//! facades run unbounded admission on a wall clock; tests and benches
//! drive the [`Engine`] directly for simulated time, admission bounds,
//! and QoS classes.

pub mod clock;
pub mod engine;
pub mod loadgen;
pub mod metrics;

pub use clock::{Clock, Timestamp};
pub use engine::{
    ingress_channel, AdmissionPolicy, Engine, IngressTx, QosClass, RejectReason, Rejected,
    Response, ServiceModel, Submission,
};
pub use loadgen::{Arrival, ArrivalProcess, Workload};
pub use metrics::ServerMetrics;

use std::sync::mpsc;
use std::time::Duration;

use crate::accel::{
    BatchPolicy, FleetConfig, MacroPool, MultiPool, PipelineOptions, PoolMode, RunStats,
};
use crate::bnn::model::MappedModel;
use crate::cam::{DegradedMode, HealthRegistry};
use crate::util::bitops::BitVec;

/// Bounded ingress depth used by [`serve_workload`]'s producer seam.
const INGRESS_CAPACITY: usize = 1024;

/// Single-tenant facade over the serving [`Engine`]: feed requests in,
/// drive the batcher + pool, collect responses.  The threaded front-end
/// ([`serve_workload`]) wraps this with producer threads over the bounded
/// ingress.
pub struct Server<'m> {
    engine: Engine<'m>,
}

impl<'m> Server<'m> {
    pub fn new(model: &'m MappedModel, opts: PipelineOptions, policy: BatchPolicy) -> Self {
        Self::with_capacity(model, opts, policy, crate::accel::DEFAULT_POOL_MACROS)
    }

    /// Server over a pool planned for an explicit macro budget (degraded
    /// budgets keep weights resident and share output macros between
    /// thresholds instead of dropping to the reload scheduler).
    pub fn with_capacity(
        model: &'m MappedModel,
        opts: PipelineOptions,
        policy: BatchPolicy,
        max_macros: usize,
    ) -> Self {
        Server {
            engine: Engine::single(model, opts, policy, max_macros),
        }
    }

    /// Execution mode of the backing pool (resident vs reload fallback).
    pub fn pool_mode(&self) -> PoolMode {
        self.engine.pool_mode(0)
    }

    /// The backing pool (diagnostics: macro count, operating points).
    pub fn pool(&self) -> &MacroPool<'m> {
        self.engine.single_pool()
    }

    /// The underlying engine (simulated clocks, admission policies, QoS —
    /// everything beyond the facade's defaults).
    pub fn engine(&self) -> &Engine<'m> {
        &self.engine
    }

    /// Enqueue one request; returns its id.  The facade's lane is
    /// unbounded (default [`AdmissionPolicy`]), so admission never
    /// rejects.
    pub fn submit(&mut self, image: BitVec) -> u64 {
        self.engine.submit(0, image).expect("facade lane is unbounded")
    }

    /// Flush pending requests as long as the policy says so (or `force`).
    /// Returns completed responses.
    ///
    /// Drains *every* ready batch, not just the first: a burst of several
    /// `max_batch`-fulls clears in one poll.  (The old single-batch drain
    /// left a bursty queue permanently behind the arrival rate — each
    /// poll removed at most one batch while the burst kept the backlog
    /// above the threshold.)
    pub fn poll(&mut self, force: bool) -> Vec<Response> {
        if force {
            self.engine.flush()
        } else {
            self.engine.poll()
        }
    }

    /// Snapshot of the service metrics.
    pub fn metrics(&self) -> ServerMetrics {
        self.engine.lane_metrics(0)
    }

    /// Clear the latency/batch-size summaries (drop warmup samples at an
    /// epoch boundary; counters keep accumulating).
    pub fn reset_latency_metrics(&mut self) {
        self.engine.reset_latency_metrics(0);
    }

    /// Drain device statistics accumulated since the *previous* call.
    ///
    /// Delta-based: each served inference is attributed to exactly one
    /// report, so calling this twice never double-counts (the pool's
    /// cycle/event counters are drained by `take_stats` and the served
    /// total is diffed against the last report).
    pub fn take_device_stats(&mut self) -> RunStats {
        self.engine.take_device_stats(0)
    }
}

/// Operator-facing per-tenant health snapshot: the lane's degradation
/// rung, the held-out macro count, probation progress, and the full
/// per-site health ladder (`cam::faults`) — everything the
/// quarantine → `un_quarantine` → probation workflow needs to watch.
#[derive(Clone, Debug)]
pub struct TenantHealth {
    /// Degradation rung as of the last maintenance turn.
    pub degraded: DegradedMode,
    /// Macros quarantined and awaiting operator re-admission.
    pub quarantined: usize,
    /// Lifetime re-admissions completed on this lane.
    pub readmissions: u64,
    /// Lifetime probation failures on this lane (each doubled the lap
    /// requirement for its macro's next attempt).
    pub probation_failures: u64,
    /// Per-site health ladder of the tenant's pool.
    pub registry: HealthRegistry,
}

/// Multi-tenant facade over the same [`Engine`]: one `MultiPool` (one
/// macro budget shared across N models), one batcher lane and one
/// [`ServerMetrics`] per tenant.  Requests are tenant-tagged at
/// submission; lanes batch independently (a device batch is always
/// tenant-homogeneous — tenants are different models) and `poll` drains
/// every lane's ready batches.
pub struct MultiServer<'m> {
    engine: Engine<'m>,
}

impl<'m> MultiServer<'m> {
    /// Server over `models` sharing `max_macros` with equal traffic
    /// shares (see `MultiPool::new`).
    pub fn new(
        models: &[&'m MappedModel],
        opts: PipelineOptions,
        policy: BatchPolicy,
        max_macros: usize,
    ) -> Self {
        Self::with_shares(models, opts, policy, max_macros, &[])
    }

    /// Server with explicit per-tenant traffic shares: surplus macro
    /// budget follows the shares (see `accel::planner::plan_tenants`);
    /// an empty slice means equal shares.
    pub fn with_shares(
        models: &[&'m MappedModel],
        opts: PipelineOptions,
        policy: BatchPolicy,
        max_macros: usize,
        shares: &[f64],
    ) -> Self {
        MultiServer {
            engine: Engine::multi(models, opts, policy, max_macros, shares),
        }
    }

    pub fn n_tenants(&self) -> usize {
        self.engine.n_lanes()
    }

    /// Attach the shared-budget maintenance supervisor (builder style):
    /// one scrub controller per tenant lane metered by deficit
    /// round-robin, so a fault-heavy tenant cannot starve its siblings'
    /// scrub cursors (see `accel::fleet` and
    /// `Engine::with_fleet_maintenance`).
    pub fn with_fleet_maintenance(mut self, seed: u64, cfg: FleetConfig) -> Self {
        self.engine = self.engine.with_fleet_maintenance(seed, cfg);
        self
    }

    /// The backing multi-tenant pool (plans, modes, diagnostics).
    pub fn pool(&self) -> &MultiPool<'m> {
        self.engine.multi_pool()
    }

    /// The underlying engine (see [`Server::engine`]).
    pub fn engine(&self) -> &Engine<'m> {
        &self.engine
    }

    /// Enqueue one request for `tenant`; returns its id (unique within
    /// the tenant's lane — pair with the tenant for a global key).  The
    /// facade's lanes are unbounded, so admission never rejects.
    pub fn submit(&mut self, tenant: usize, image: BitVec) -> u64 {
        self.engine.submit(tenant, image).expect("lanes are unbounded")
    }

    /// Flush every tenant lane as long as its policy says so (or `force`).
    /// Returns completed responses across all tenants.  Like
    /// [`Server::poll`], each lane drains *every* ready batch per call.
    pub fn poll(&mut self, force: bool) -> Vec<Response> {
        if force {
            self.engine.flush()
        } else {
            self.engine.poll()
        }
    }

    /// Snapshot of one tenant's service metrics.
    pub fn metrics(&self, tenant: usize) -> ServerMetrics {
        self.engine.lane_metrics(tenant)
    }

    /// One tenant's health snapshot (degraded rung + macro ladder).
    pub fn health(&self, tenant: usize) -> TenantHealth {
        let m = self.engine.lane_metrics(tenant);
        let pool = self.engine.multi_pool().tenant(tenant);
        TenantHealth {
            degraded: m.degraded,
            quarantined: pool.health_quarantined(),
            readmissions: m.readmissions,
            probation_failures: m.probation_failures,
            registry: pool.health_registry(),
        }
    }

    /// Every tenant's health snapshot, lane order.
    pub fn health_snapshot(&self) -> Vec<TenantHealth> {
        (0..self.n_tenants()).map(|t| self.health(t)).collect()
    }

    /// Operator re-admission of a quarantined macro in `tenant`'s pool:
    /// it goes on probation and earns its way back through canary laps
    /// (see `MacroPool::un_quarantine`).  Returns `false` when nothing
    /// on that load is quarantined.
    pub fn un_quarantine(&self, tenant: usize, layer: usize, load: usize) -> bool {
        self.engine.multi_pool().un_quarantine(tenant, layer, load)
    }

    /// Clear one tenant's latency/batch-size summaries (epoch boundary).
    pub fn reset_latency_metrics(&mut self, tenant: usize) {
        self.engine.reset_latency_metrics(tenant);
    }

    /// Drain one tenant's device statistics accumulated since the
    /// previous call for that tenant (delta-based, like
    /// [`Server::take_device_stats`]).
    pub fn take_device_stats(&mut self, tenant: usize) -> RunStats {
        self.engine.take_device_stats(tenant)
    }
}

/// Outcome of a [`serve_workload`] run: the lane's service metrics plus
/// the typed admission rejections ([`RejectReason`] tallied by variant).
/// `metrics.shed` equals the sum of the rejection counters — the summary
/// just keeps the reasons apart so callers can tell queue-bound shedding
/// from ingress backpressure.
#[derive(Clone, Debug, Default)]
pub struct WorkloadSummary {
    pub metrics: ServerMetrics,
    /// Lane at its admission depth bound ([`RejectReason::QueueFull`]).
    pub rejected_queue_full: u64,
    /// Bounded ingress ring full ([`RejectReason::IngressFull`]) — the
    /// closed-loop producers block instead, so this stays zero unless a
    /// driver switches to `try_submit`.
    pub rejected_ingress_full: u64,
    /// Submission raced shutdown ([`RejectReason::ShuttingDown`]).
    pub rejected_shutting_down: u64,
    /// Pool degraded past every recovery rung
    /// ([`RejectReason::Degraded`]) — the typed refusal that replaces
    /// silently wrong answers.
    pub rejected_degraded: u64,
}

impl WorkloadSummary {
    fn count(&mut self, rejected: &Rejected) {
        match rejected.reason {
            RejectReason::QueueFull { .. } => self.rejected_queue_full += 1,
            RejectReason::IngressFull { .. } => self.rejected_ingress_full += 1,
            RejectReason::ShuttingDown => self.rejected_shutting_down += 1,
            RejectReason::Degraded => self.rejected_degraded += 1,
        }
    }
}

/// Drive a server with a workload produced by `n_producers` threads, each
/// submitting a share of `images` with `inter_arrival` spacing through
/// the bounded ingress.  Returns (responses in completion order, metrics).
pub fn serve_workload(
    model: &MappedModel,
    opts: PipelineOptions,
    policy: BatchPolicy,
    images: &[BitVec],
    n_producers: usize,
    inter_arrival: Duration,
) -> (Vec<Response>, ServerMetrics) {
    serve_workload_with_capacity(
        model,
        opts,
        policy,
        images,
        n_producers,
        inter_arrival,
        crate::accel::DEFAULT_POOL_MACROS,
    )
}

/// [`serve_workload`] over a pool planned for an explicit macro budget
/// (unbounded admission: the historical facade behaviour).
#[allow(clippy::too_many_arguments)]
pub fn serve_workload_with_capacity(
    model: &MappedModel,
    opts: PipelineOptions,
    policy: BatchPolicy,
    images: &[BitVec],
    n_producers: usize,
    inter_arrival: Duration,
    max_macros: usize,
) -> (Vec<Response>, ServerMetrics) {
    let (responses, summary) = serve_workload_with_admission(
        model,
        opts,
        policy,
        images,
        n_producers,
        inter_arrival,
        max_macros,
        AdmissionPolicy::default(),
    );
    (responses, summary.metrics)
}

/// [`serve_workload`] through the full QoS machinery: the lane runs the
/// given [`AdmissionPolicy`] (class + depth bound), refused submissions
/// are tallied by typed reason in the [`WorkloadSummary`], and the
/// consumer parks on the ingress until the earliest batch deadline
/// instead of spin-polling on a fixed interval.
#[allow(clippy::too_many_arguments)]
pub fn serve_workload_with_admission(
    model: &MappedModel,
    opts: PipelineOptions,
    policy: BatchPolicy,
    images: &[BitVec],
    n_producers: usize,
    inter_arrival: Duration,
    max_macros: usize,
    admission: AdmissionPolicy,
) -> (Vec<Response>, WorkloadSummary) {
    let subs: Vec<Submission> = images
        .iter()
        .map(|img| Submission {
            tenant: 0,
            image: img.clone(),
            budget: None,
        })
        .collect();
    drive_submissions(
        model,
        opts,
        policy,
        subs,
        n_producers,
        inter_arrival,
        max_macros,
        admission,
    )
}

/// [`serve_workload_with_admission`] with an explicit end-to-end latency
/// budget per request, carried through the ingress ring in the
/// [`Submission`] message: request `i` travels with `budgets[i]`, and its
/// lane closes the batch once half that budget is spent queueing (the
/// half-budget rule — see `accel::batcher`).  The plain facades send
/// `budget: None`, which the dispatch loop resolves to the lane's
/// [`Engine::default_budget`].
#[allow(clippy::too_many_arguments)]
pub fn serve_workload_with_budgets(
    model: &MappedModel,
    opts: PipelineOptions,
    policy: BatchPolicy,
    images: &[BitVec],
    budgets: &[Duration],
    n_producers: usize,
    inter_arrival: Duration,
    max_macros: usize,
    admission: AdmissionPolicy,
) -> (Vec<Response>, WorkloadSummary) {
    assert_eq!(images.len(), budgets.len(), "one budget per request");
    let subs: Vec<Submission> = images
        .iter()
        .zip(budgets)
        .map(|(img, b)| Submission {
            tenant: 0,
            image: img.clone(),
            budget: Some(*b),
        })
        .collect();
    drive_submissions(
        model,
        opts,
        policy,
        subs,
        n_producers,
        inter_arrival,
        max_macros,
        admission,
    )
}

/// The shared closed-loop driver behind the `serve_workload_*` facades:
/// producer threads feed pre-built [`Submission`]s through the bounded
/// ingress, the consumer runs the engine's dispatch loop parked on the
/// ring between arrivals.
#[allow(clippy::too_many_arguments)]
fn drive_submissions(
    model: &MappedModel,
    opts: PipelineOptions,
    policy: BatchPolicy,
    subs: Vec<Submission>,
    n_producers: usize,
    inter_arrival: Duration,
    max_macros: usize,
    admission: AdmissionPolicy,
) -> (Vec<Response>, WorkloadSummary) {
    let n = subs.len();
    let (tx, rx) = ingress_channel(INGRESS_CAPACITY);
    std::thread::scope(|s| {
        // producers feed the bounded ingress (blocking sends: a closed
        // loop never sheds at the ring, it backpressures the producers;
        // shedding happens at lane admission under a bounded policy)
        let per = n.div_ceil(n_producers.max(1));
        for chunk in subs.chunks(per.max(1)) {
            let tx = tx.clone();
            s.spawn(move || {
                for sub in chunk {
                    if tx.submit_blocking(sub.clone()).is_err() {
                        return;
                    }
                    if !inter_arrival.is_zero() {
                        std::thread::sleep(inter_arrival);
                    }
                }
            });
        }
        drop(tx);
        // consumer: the engine's dispatch loop, parked on the ingress
        // between arrivals and woken at the earliest lane deadline
        let engine =
            Engine::single(model, opts, policy, max_macros).with_admission(0, admission);
        let mut responses = Vec::with_capacity(n);
        let mut summary = WorkloadSummary::default();
        loop {
            let wait = match engine.next_deadline() {
                // idle: nothing becomes ready until a submission lands,
                // so only the ingress can make work (generous timeout)
                None => Duration::from_millis(50),
                Some(deadline) => {
                    let remaining = deadline.saturating_sub(engine.clock().now());
                    if remaining.is_zero() {
                        // a batch is due: serve before waiting again
                        responses.extend(engine.poll());
                        continue;
                    }
                    remaining
                }
            };
            match rx.recv_timeout(wait) {
                Ok(sub) => {
                    // a message without a budget gets the lane's default
                    // here at the dispatch seam, so every admitted
                    // request carries an explicit end-to-end budget
                    let budget = sub
                        .budget
                        .unwrap_or_else(|| engine.default_budget(sub.tenant));
                    if let Err(rejected) =
                        engine.submit_with_budget(sub.tenant, sub.image, budget)
                    {
                        summary.count(&rejected);
                    }
                    responses.extend(engine.poll());
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    responses.extend(engine.poll());
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    responses.extend(engine.flush());
                    break;
                }
            }
        }
        summary.metrics = engine.lane_metrics(0);
        (responses, summary)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Pipeline;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::cam::NoiseMode;
    use crate::util::rng::Rng;

    fn images(n: usize, bits: usize) -> Vec<BitVec> {
        let mut rng = Rng::new(8, 8);
        (0..n)
            .map(|_| {
                let mut v = BitVec::zeros(bits);
                for i in 0..bits {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect()
    }

    fn opts() -> PipelineOptions {
        PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        }
    }

    #[test]
    fn serves_all_requests_once() {
        let model = tiny_model(64, 8, 3, 31);
        let imgs = images(40, 64);
        let (responses, metrics) = serve_workload(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
            },
            &imgs,
            3,
            Duration::ZERO,
        );
        assert_eq!(responses.len(), 40);
        assert_eq!(metrics.served, 40);
        assert_eq!(metrics.admitted, 40, "every request admitted");
        assert_eq!(metrics.shed, 0, "unbounded lane never sheds");
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 40, "every id exactly once");
        assert!(metrics.mean_batch() >= 1.0);
    }

    #[test]
    fn predictions_match_direct_pipeline() {
        let model = tiny_model(64, 8, 3, 32);
        let imgs = images(16, 64);
        let (mut responses, _) = serve_workload(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(50),
            },
            &imgs,
            1,
            Duration::ZERO,
        );
        responses.sort_by_key(|r| r.id);
        let mut pipe = Pipeline::new(&model, opts());
        let want = pipe.classify_batch(&imgs);
        for (r, (votes, pred)) in responses.iter().zip(&want) {
            assert_eq!(&r.prediction, pred);
            assert_eq!(&r.votes, votes);
        }
    }

    #[test]
    fn bounded_admission_workload_sheds_typed_in_the_summary() {
        // satellite: serve_workload through the QoS machinery — a depth
        // bound smaller than the batch size means the lane can hold 2
        // requests that never close (huge deadline), so every later
        // submission is refused QueueFull and tallied by reason
        let model = tiny_model(64, 8, 3, 46);
        let imgs = images(64, 64);
        let (responses, summary) = serve_workload_with_admission(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_secs(60),
            },
            &imgs,
            4,
            Duration::ZERO,
            crate::accel::DEFAULT_POOL_MACROS,
            AdmissionPolicy {
                class: QosClass::BestEffort,
                max_depth: 2,
            },
        );
        assert_eq!(responses.len(), 2, "only the depth bound survives");
        assert_eq!(summary.rejected_queue_full, 62);
        assert_eq!(summary.rejected_ingress_full, 0, "producers block, never shed");
        assert_eq!(summary.rejected_shutting_down, 0);
        assert_eq!(summary.metrics.admitted, 2);
        assert_eq!(summary.metrics.shed, 62, "lane metrics agree with the tally");
        assert_eq!(summary.metrics.served, 2);
    }

    #[test]
    fn per_request_budgets_ride_the_ingress_ring() {
        // satellite: explicit latency budgets travel in the Submission
        // message and every request still completes exactly once
        let model = tiny_model(64, 8, 3, 48);
        let imgs = images(12, 64);
        let budgets: Vec<Duration> = (0..imgs.len())
            .map(|i| Duration::from_millis(1 + i as u64))
            .collect();
        let (responses, summary) = serve_workload_with_budgets(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(100),
            },
            &imgs,
            &budgets,
            2,
            Duration::ZERO,
            crate::accel::DEFAULT_POOL_MACROS,
            AdmissionPolicy::default(),
        );
        assert_eq!(responses.len(), 12);
        assert_eq!(summary.metrics.served, 12);
        assert_eq!(summary.metrics.shed, 0);
        assert_eq!(summary.rejected_degraded, 0);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 12, "every id exactly once");
    }

    #[test]
    fn unbounded_admission_summary_reports_no_rejections() {
        let model = tiny_model(64, 8, 3, 47);
        let imgs = images(24, 64);
        let (responses, summary) = serve_workload_with_admission(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
            },
            &imgs,
            3,
            Duration::ZERO,
            crate::accel::DEFAULT_POOL_MACROS,
            AdmissionPolicy::default(),
        );
        assert_eq!(responses.len(), 24);
        assert_eq!(summary.rejected_queue_full, 0);
        assert_eq!(summary.metrics.shed, 0);
        assert_eq!(summary.metrics.served, 24);
    }

    #[test]
    fn force_poll_flushes_partial_batch() {
        let model = tiny_model(64, 8, 3, 33);
        let mut server = Server::new(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 100,
                max_wait: Duration::from_secs(60),
            },
        );
        server.submit(images(1, 64).pop().unwrap());
        assert!(server.poll(false).is_empty(), "policy not yet ready");
        let got = server.poll(true);
        assert_eq!(got.len(), 1);
    }

    #[test]
    fn burst_of_full_batches_clears_in_one_poll() {
        // regression: poll(force=false) used to drain at most one
        // max_batch per call, so a burst left the queue permanently
        // behind the arrival rate
        let model = tiny_model(64, 8, 3, 36);
        let mut server = Server::new(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_secs(60),
            },
        );
        for img in images(3 * 8, 64) {
            server.submit(img);
        }
        let got = server.poll(false);
        assert_eq!(got.len(), 24, "3×max_batch burst must clear in one poll");
        assert_eq!(
            server.metrics().batches,
            3,
            "drained as policy-sized batches"
        );
        assert!(server.poll(false).is_empty(), "queue actually empty");
    }

    #[test]
    fn poll_drains_timed_out_partial_batch_after_full_ones() {
        let model = tiny_model(64, 8, 3, 37);
        let mut server = Server::new(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO, // everything is instantly due
            },
        );
        for img in images(2 * 8 + 3, 64) {
            server.submit(img);
        }
        let got = server.poll(false);
        assert_eq!(got.len(), 19, "two full batches + the due partial one");
        assert_eq!(server.metrics().batches, 3);
    }

    #[test]
    fn degraded_budget_serves_resident_with_bounded_retunes() {
        // tentpole acceptance at the server layer: a model whose full
        // residency exceeds the budget still serves with zero
        // steady-state programming and a planned, bounded retune cost
        let model = tiny_model(64, 8, 3, 38);
        let required = MacroPool::macros_required(&model, &opts());
        let budget = required / 2;
        let mut server = Server::with_capacity(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO,
            },
            budget,
        );
        assert_eq!(server.pool_mode(), PoolMode::Resident);
        let predicted = server.pool().plan().unwrap().predicted_retunes_per_batch();
        assert!(predicted > 0, "sharing must be active at half budget");
        // warmup epoch
        for img in images(8, 64) {
            server.submit(img);
        }
        server.poll(true);
        server.take_device_stats();
        // steady state: zero programming, retunes bounded by the plan
        for img in images(8, 64) {
            server.submit(img);
        }
        server.poll(true);
        let steady = server.take_device_stats();
        assert_eq!(steady.programming_cycles(), 0);
        assert!(steady.events.retunes > 0);
        assert!(steady.events.retunes <= predicted);
        assert_eq!(steady.hidden_cost.retunes, 0);
        assert_eq!(steady.output_cost.retunes, steady.events.retunes);
        // and the predictions still match the reload pipeline bit-exactly
        let imgs = images(8, 64);
        for img in &imgs {
            server.submit(img.clone());
        }
        let mut responses = server.poll(true);
        responses.sort_by_key(|r| r.id);
        let mut pipe = Pipeline::new(&model, opts());
        let want = pipe.classify_batch(&imgs);
        for (r, (votes, pred)) in responses.iter().zip(&want) {
            assert_eq!(&r.prediction, pred);
            assert_eq!(&r.votes, votes);
        }
    }

    #[test]
    fn device_stats_are_delta_based_not_cumulative() {
        // regression: take_device_stats used to re-report the cumulative
        // served count on every call
        let model = tiny_model(64, 8, 3, 34);
        let mut server = Server::new(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO,
            },
        );
        for img in images(8, 64) {
            server.submit(img);
        }
        assert_eq!(server.poll(true).len(), 8);
        let first = server.take_device_stats();
        assert_eq!(first.inferences, 8);
        assert!(first.cycles > 0);
        // nothing served in between: second report must be empty
        let second = server.take_device_stats();
        assert_eq!(second.inferences, 0, "cumulative double count");
        assert_eq!(second.cycles, 0, "device counters not drained");
        // serve more: only the new inferences appear
        for img in images(5, 64) {
            server.submit(img);
        }
        assert_eq!(server.poll(true).len(), 5);
        let third = server.take_device_stats();
        assert_eq!(third.inferences, 5);
        assert!(third.cycles > 0);
    }

    #[test]
    fn idle_server_reports_nan_percentiles_not_a_panic() {
        // regression guard: percentile over an empty latency reservoir
        // must return the documented NaN sentinel, never index-panic
        let model = tiny_model(64, 8, 3, 39);
        let server = Server::new(&model, opts(), BatchPolicy::default());
        assert!(server.metrics().p50_ms().is_nan());
        assert!(server.metrics().p99_ms().is_nan());
        assert!(server.metrics().p999_ms().is_nan());
        assert!(server.metrics().mean_batch().is_nan());
        // a multi-tenant server's idle lanes behave the same way
        let b = tiny_model(64, 8, 3, 40);
        let multi = MultiServer::new(&[&model, &b], opts(), BatchPolicy::default(), 16);
        for t in 0..multi.n_tenants() {
            let m = multi.metrics(t);
            assert!(m.p50_ms().is_nan());
            assert!(m.p99_ms().is_nan());
        }
    }

    #[test]
    fn multi_server_serves_two_tenants_from_one_budget() {
        // tentpole acceptance at the server layer: one budget, two model
        // shapes, per-tenant metrics, zero steady-state programming, and
        // per-tenant predictions bit-identical to standalone pools
        let a = tiny_model(100, 16, 4, 41);
        let b = tiny_model(64, 8, 3, 42);
        let budget =
            MacroPool::macros_required(&a, &opts()) + MacroPool::macros_required(&b, &opts());
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::ZERO,
        };
        let mut server = MultiServer::new(&[&a, &b], opts(), policy, budget);
        assert_eq!(server.n_tenants(), 2);
        assert_eq!(server.pool().tenant(0).mode(), PoolMode::Resident);
        assert_eq!(server.pool().tenant(1).mode(), PoolMode::Resident);
        let imgs_a = images(8, 100);
        let imgs_b = images(8, 64);
        // warmup epoch: interleaved tenant submissions
        for (ia, ib) in imgs_a.iter().zip(&imgs_b) {
            server.submit(0, ia.clone());
            server.submit(1, ib.clone());
        }
        server.poll(true);
        server.take_device_stats(0);
        server.take_device_stats(1);
        // steady state: both tenants pay zero programming and zero retunes
        for (ia, ib) in imgs_a.iter().zip(&imgs_b) {
            server.submit(0, ia.clone());
            server.submit(1, ib.clone());
        }
        let mut responses = server.poll(true);
        for t in 0..2 {
            let steady = server.take_device_stats(t);
            assert_eq!(steady.inferences, 8, "tenant {t}");
            assert_eq!(steady.programming_cycles(), 0, "tenant {t}");
            assert_eq!(steady.events.retunes, 0, "tenant {t}");
            assert_eq!(server.metrics(t).served, 16, "tenant {t}");
        }
        // per-tenant predictions match the reload pipelines bit-exactly
        responses.sort_by_key(|r| (r.tenant, r.id));
        let (ra, rb): (Vec<_>, Vec<_>) = responses.into_iter().partition(|r| r.tenant == 0);
        let mut pipe_a = Pipeline::new(&a, opts());
        let mut pipe_b = Pipeline::new(&b, opts());
        // the steady-state epoch re-served the same images
        let want_a = pipe_a.classify_batch(&imgs_a);
        let want_b = pipe_b.classify_batch(&imgs_b);
        for (r, (votes, pred)) in ra.iter().zip(&want_a) {
            assert_eq!(&r.prediction, pred);
            assert_eq!(&r.votes, votes);
        }
        for (r, (votes, pred)) in rb.iter().zip(&want_b) {
            assert_eq!(&r.prediction, pred);
            assert_eq!(&r.votes, votes);
        }
    }

    #[test]
    fn multi_server_partial_batches_flush_per_lane() {
        let a = tiny_model(64, 8, 3, 43);
        let b = tiny_model(64, 8, 3, 44);
        let mut server = MultiServer::new(
            &[&a, &b],
            opts(),
            BatchPolicy {
                max_batch: 100,
                max_wait: Duration::from_secs(60),
            },
            16,
        );
        server.submit(0, images(1, 64).pop().unwrap());
        server.submit(1, images(1, 64).pop().unwrap());
        assert!(server.poll(false).is_empty(), "policies not yet ready");
        let got = server.poll(true);
        assert_eq!(got.len(), 2);
        let tenants: Vec<usize> = got.iter().map(|r| r.tenant).collect();
        assert!(tenants.contains(&0) && tenants.contains(&1));
        assert_eq!(server.metrics(0).served, 1);
        assert_eq!(server.metrics(1).served, 1);
    }

    #[test]
    fn server_runs_resident_and_pays_no_steady_state_programming() {
        let model = tiny_model(64, 8, 3, 35);
        let mut server = Server::new(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO,
            },
        );
        assert_eq!(server.pool_mode(), PoolMode::Resident);
        // warmup epoch: construction programming drains with the first take
        for img in images(8, 64) {
            server.submit(img);
        }
        server.poll(true);
        server.take_device_stats();
        // steady state: zero programming / retunes
        for img in images(8, 64) {
            server.submit(img);
        }
        server.poll(true);
        let steady = server.take_device_stats();
        assert_eq!(steady.programming_cycles(), 0);
        assert_eq!(steady.events.retunes, 0);
        assert!(steady.events.searches > 0);
    }
}
