//! The staged serving engine both server facades share:
//!
//! ```text
//!   ingress (bounded MPSC)  →  admission (QoS, depth bounds)  →  lanes
//!        →  executor (drains ready batches into the resident pool)
//! ```
//!
//! **Ingress** — [`ingress_channel`] is a bounded std MPSC seam between
//! producer threads and the engine.  A full ring rejects with the typed
//! [`RejectReason::IngressFull`] instead of queueing unboundedly; the
//! open-loop drivers and `serve_workload` feed the engine through it.
//!
//! **Admission** — every lane carries an [`AdmissionPolicy`]: a QoS class
//! and a queue-depth bound.  A submission to a full lane is refused with
//! [`RejectReason::QueueFull`] — typed backpressure the caller can act
//! on — and counted in the lane's `shed` metric.  Bounded depths are what
//! keep latency bounded under overload: a lane can never owe more than
//! `max_depth` requests of work.
//!
//! **Lanes** — one [`Batcher`] per tenant.  A batch closes when full or
//! when the oldest request has spent **half its latency budget** queueing
//! (the other half is reserved for service; see `accel::batcher`).
//!
//! **Executor** — [`Engine::poll`] is one scheduler tick: it reads the
//! [`Clock`] **once** for all readiness decisions, then drains every
//! ready batch, guaranteed-class lanes strictly before best-effort ones.
//! Under overload the guaranteed class therefore keeps its (bounded)
//! queueing delay while best-effort traffic is shed at admission — the
//! overload contract the serving bench asserts.  Batches classify on the
//! shared allocation-free `classify_batch` path; multiple worker threads
//! may call `poll` concurrently (lane locks cover only drain/record, the
//! classify runs lock-free on the pool's scratch arenas).
//!
//! **Maintenance** — every tick ends with one turn of the registered
//! maintenance tasks, after all ready batches have drained: the
//! online re-planning controller ([`Engine::with_replan`]) applies at
//! most one live-migration step per gap — a batch never waits on bulk
//! migration work — and periodic pacing recalibration
//! ([`Engine::with_recalibration`]) re-measures `DevicePaced` from
//! served-stat deltas so simulations track device-time drift.
//! Maintenance reads no clock, so the hoisted-read contract holds.
//!
//! **Parked workers** — [`Engine::poll_or_park`] replaces spin-polling:
//! an idle worker blocks on a condvar signalled by every admitted
//! submission, waking early at the earliest lane deadline
//! (`Batcher::next_deadline`).  An idle engine burns no CPU and
//! performs zero ticks between arrivals ([`Engine::ticks`] pins this).
//!
//! **Determinism** — a request's lane id doubles as its noise-stream
//! index ([`Request::id`]), so predictions and RNG draw order depend only
//! on each lane's admission order, never on batch shapes, poll timing,
//! or worker interleaving.  `rust/tests/props.rs` pins async ≡ sync
//! bit-exactness on top of this invariant.  With a simulated [`Clock`]
//! and a [`ServiceModel::DevicePaced`] pacing model the whole engine
//! becomes a deterministic discrete-event simulation (latency
//! distributions included) — that is how `benches/serving.rs` measures
//! p50/p99/p999 under overload reproducibly.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::accel::{
    BatchPolicy, Batcher, FleetConfig, FleetMaintenance, MacroPool, MultiPool, PipelineOptions,
    PoolMode, ReplanConfig, ReplanController, Request, RunStats, ScrubConfig, ScrubController,
};
use crate::bnn::model::MappedModel;
use crate::cam::DegradedMode;
use crate::server::clock::{Clock, NoClockReads, Timestamp};
use crate::server::metrics::ServerMetrics;
use crate::util::bitops::BitVec;

/// A classification response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Tenant that served the request (0 for single-model servers).  Ids
    /// are unique per tenant lane, so (tenant, id) identifies a request.
    pub tenant: usize,
    pub prediction: usize,
    pub votes: Vec<u32>,
    pub latency: Duration,
}

/// Service class of a lane: guaranteed lanes drain strictly before
/// best-effort lanes on every scheduler tick, so under overload the
/// best-effort class absorbs the queueing (and, with bounded depths, the
/// shedding) first.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QosClass {
    Guaranteed,
    BestEffort,
}

/// Per-lane admission policy: QoS class + queue-depth bound.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    pub class: QosClass,
    /// Submissions are refused once this many requests are pending.
    pub max_depth: usize,
}

impl Default for AdmissionPolicy {
    /// Guaranteed class, unbounded depth — the facade default, under
    /// which `submit` never rejects (pre-engine behaviour).
    fn default() -> Self {
        AdmissionPolicy {
            class: QosClass::Guaranteed,
            max_depth: usize::MAX,
        }
    }
}

/// Why a submission was refused.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The lane's queue is at its admission bound.
    QueueFull { pending: usize, limit: usize },
    /// The bounded ingress ring is full (producer-side backpressure).
    IngressFull { capacity: usize },
    /// The lane's pool has degraded past every recovery rung
    /// ([`DegradedMode::Refusing`]): refusing new work is the typed
    /// alternative to serving silently wrong answers.
    Degraded,
    /// The engine side of the ingress hung up.
    ShuttingDown,
}

/// Typed rejection — the backpressure signal replacing unbounded queues.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Rejected {
    pub tenant: usize,
    pub reason: RejectReason,
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.reason {
            RejectReason::QueueFull { pending, limit } => write!(
                f,
                "tenant {}: queue full ({pending} pending, limit {limit})",
                self.tenant
            ),
            RejectReason::IngressFull { capacity } => {
                write!(f, "tenant {}: ingress full (capacity {capacity})", self.tenant)
            }
            RejectReason::Degraded => write!(
                f,
                "tenant {}: pool degraded beyond recovery, refusing service",
                self.tenant
            ),
            RejectReason::ShuttingDown => write!(f, "tenant {}: shutting down", self.tenant),
        }
    }
}

/// How completion time is stamped.
#[derive(Clone, Debug)]
pub enum ServiceModel {
    /// Real time passes during `classify_batch` (wall-clock serving).
    HostPaced,
    /// After each batch the engine advances its (simulated) clock by
    /// `per_image[lane] × batch_len` — the device-time cost model that
    /// turns the engine into a deterministic discrete-event simulation.
    /// Requires a simulated [`Clock`]; see
    /// [`Engine::calibrate_device_pacing`].
    DevicePaced(Vec<Duration>),
}

/// One tenant lane: admission policy + mutex-guarded queue/metrics state.
struct Lane {
    admission: AdmissionPolicy,
    state: Mutex<LaneState>,
}

struct LaneState {
    batcher: Batcher,
    metrics: ServerMetrics,
    /// Inferences already reported by `take_device_stats` (delta base).
    stats_reported: u64,
}

enum Backend<'m> {
    Single(MacroPool<'m>),
    Multi(MultiPool<'m>),
}

/// Work the engine runs in the gaps between batches: one turn per task
/// per tick, after every ready batch has drained (module docs).
enum MaintenanceTask {
    /// Online re-planning for one lane's pool: the controller applies at
    /// most one live-migration step per turn.
    Replan {
        lane: usize,
        controller: ReplanController,
    },
    /// Every `period` ticks, re-measure per-lane device pacing from the
    /// served-stat deltas and swap it into the `DevicePaced` model.
    Recalibrate { period: u64, ticks: u64 },
    /// Scrub-and-repair for one lane's pool: each turn spends a bounded
    /// row budget read-verifying resident weights (plus canary
    /// searches), repairing in place and escalating per `accel::scrub`.
    Scrub {
        lane: usize,
        controller: ScrubController,
    },
    /// Fleet-wide maintenance for a multi-tenant engine: one shared
    /// scrub-row budget metered across every lane by deficit round-robin
    /// (plus an optional re-planning controller per lane), per
    /// `accel::fleet` — supersedes per-lane `Scrub`/`Replan` tasks.
    Fleet { supervisor: FleetMaintenance },
}

/// The unified serving core (module docs).  `Server` and `MultiServer`
/// are thin facades over this type; tests and benches drive it directly
/// for simulated time, admission control, and multi-worker polling.
pub struct Engine<'m> {
    backend: Backend<'m>,
    lanes: Vec<Lane>,
    clock: Clock,
    /// Mutex so periodic recalibration can re-pace a running engine; the
    /// executor holds it only to read the per-batch advance.
    service: Mutex<ServiceModel>,
    /// Inter-batch maintenance tasks (module docs).  `try_lock` in the
    /// tick path: concurrent workers never queue behind a migration step.
    maintenance: Mutex<Vec<MaintenanceTask>>,
    /// Scheduler ticks executed (poll + flush) — the parked-worker tests
    /// pin that an idle engine performs zero ticks between arrivals.
    ticks: AtomicU64,
    /// Admitted-submission generation; bumped under the mutex and
    /// signalled so parked workers wake on arrival.
    arrivals: Mutex<u64>,
    arrival_cv: Condvar,
}

impl<'m> Engine<'m> {
    /// Single-tenant engine over a pool planned for `max_macros`.
    pub fn single(
        model: &'m MappedModel,
        opts: PipelineOptions,
        policy: BatchPolicy,
        max_macros: usize,
    ) -> Self {
        Self::from_parts(
            Backend::Single(MacroPool::with_capacity(model, opts, max_macros)),
            vec![Lane::new(policy)],
        )
    }

    /// Multi-tenant engine: one lane per model over one shared budget
    /// (empty `shares` = equal traffic shares; see `MultiPool`).
    pub fn multi(
        models: &[&'m MappedModel],
        opts: PipelineOptions,
        policy: BatchPolicy,
        max_macros: usize,
        shares: &[f64],
    ) -> Self {
        let pool = MultiPool::with_shares(models, opts, max_macros, 1, shares);
        let n = pool.n_tenants();
        Self::from_parts(
            Backend::Multi(pool),
            (0..n).map(|_| Lane::new(policy)).collect(),
        )
    }

    fn from_parts(backend: Backend<'m>, lanes: Vec<Lane>) -> Self {
        Engine {
            backend,
            lanes,
            clock: Clock::wall(),
            service: Mutex::new(ServiceModel::HostPaced),
            maintenance: Mutex::new(Vec::new()),
            ticks: AtomicU64::new(0),
            arrivals: Mutex::new(0),
            arrival_cv: Condvar::new(),
        }
    }

    /// Replace the time source (builder style; simulated clocks make
    /// every scheduling decision replayable).
    pub fn with_clock(mut self, clock: Clock) -> Self {
        self.clock = clock;
        self
    }

    /// Replace the completion-pacing model.  `DevicePaced` requires a
    /// simulated clock (it advances the timeline per batch).
    pub fn with_service(mut self, service: ServiceModel) -> Self {
        if matches!(service, ServiceModel::DevicePaced(_)) {
            assert!(
                self.clock.is_simulated(),
                "DevicePaced service requires a simulated clock"
            );
        }
        self.service = Mutex::new(service);
        self
    }

    /// Set one lane's admission policy (builder style).
    pub fn with_admission(mut self, lane: usize, admission: AdmissionPolicy) -> Self {
        self.lanes[lane].admission = admission;
        self
    }

    /// Register the online re-planning maintenance task for one lane:
    /// every tick applies at most one live-migration step to that lane's
    /// pool, in the gap after ready batches drain (see `accel::replan`
    /// for the period/EWMA/hysteresis/horizon knobs).  Steps applied,
    /// cycles spent, and predicted retunes saved surface in the lane's
    /// [`ServerMetrics`].
    pub fn with_replan(self, lane: usize, budget: usize, cfg: ReplanConfig) -> Self {
        let controller = match &self.backend {
            Backend::Single(p) => {
                assert_eq!(lane, 0, "single-tenant engines have one lane");
                ReplanController::new(p, budget, cfg)
            }
            Backend::Multi(p) => ReplanController::new(p.tenant(lane), budget, cfg),
        };
        self.maintenance
            .lock()
            .unwrap()
            .push(MaintenanceTask::Replan { lane, controller });
        self
    }

    /// Register periodic device-pacing recalibration: every `period`
    /// ticks the engine re-measures each lane's device time per
    /// inference from the stats served since the last report and swaps
    /// it into the `DevicePaced` model, so long simulations track drift
    /// (a lane that served nothing keeps its pacing; host-paced engines
    /// ignore the task).  Consumes the same delta stream as
    /// [`Self::take_device_stats`] — don't drain stats manually on a
    /// recalibrating engine.
    pub fn with_recalibration(self, period: u64) -> Self {
        assert!(period >= 1, "recalibration period must be at least one tick");
        self.maintenance
            .lock()
            .unwrap()
            .push(MaintenanceTask::Recalibrate { period, ticks: 0 });
        self
    }

    /// Register the scrub-and-repair maintenance task for one lane: each
    /// tick spends `cfg.rows_per_turn` rows read-verifying that lane's
    /// resident pool against the golden weights (plus canary searches),
    /// repairs in place, and escalates through rebuild → quarantine →
    /// typed refusal (see `accel::scrub`).  Scrub progress, detections,
    /// repairs, and the pool's [`DegradedMode`] surface in the lane's
    /// [`ServerMetrics`]; a pool that reaches `Refusing` rejects new
    /// submissions with [`RejectReason::Degraded`].
    pub fn with_scrub(self, lane: usize, seed: u64, cfg: ScrubConfig) -> Self {
        if matches!(self.backend, Backend::Single(_)) {
            assert_eq!(lane, 0, "single-tenant engines have one lane");
        }
        assert!(lane < self.lanes.len(), "scrub lane out of range");
        self.maintenance.lock().unwrap().push(MaintenanceTask::Scrub {
            lane,
            controller: ScrubController::new(seed, cfg),
        });
        self
    }

    /// Register fleet-wide maintenance on a multi-tenant engine: one
    /// shared scrub-row budget per inter-batch gap, metered across every
    /// lane by deficit round-robin, plus an optional re-planning
    /// controller per resident lane (see `accel::fleet`).  Use this in
    /// place of per-lane [`Self::with_scrub`]/[`Self::with_replan`]
    /// chains when tenants share a gap: a fault-heavy tenant spends only
    /// its own credit and can no longer starve its siblings' scrub
    /// cursors.  Panics on a single-tenant engine.
    pub fn with_fleet_maintenance(self, seed: u64, cfg: FleetConfig) -> Self {
        let supervisor = match &self.backend {
            Backend::Single(_) => panic!("fleet maintenance supervises a multi-tenant engine"),
            Backend::Multi(p) => FleetMaintenance::new(p, seed, cfg),
        };
        self.maintenance
            .lock()
            .unwrap()
            .push(MaintenanceTask::Fleet { supervisor });
        self
    }

    /// Snapshot of the completion-pacing model (recalibration may have
    /// replaced the one installed at build time).
    pub fn service_model(&self) -> ServiceModel {
        self.service.lock().unwrap().clone()
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// The backing single-tenant pool (panics on a multi-tenant engine).
    pub fn single_pool(&self) -> &MacroPool<'m> {
        match &self.backend {
            Backend::Single(p) => p,
            Backend::Multi(_) => panic!("single_pool on a multi-tenant engine"),
        }
    }

    /// The backing multi-tenant pool (panics on a single-tenant engine).
    pub fn multi_pool(&self) -> &MultiPool<'m> {
        match &self.backend {
            Backend::Single(_) => panic!("multi_pool on a single-tenant engine"),
            Backend::Multi(p) => p,
        }
    }

    /// Execution mode of a lane's backing pool.
    pub fn pool_mode(&self, lane: usize) -> PoolMode {
        match &self.backend {
            Backend::Single(p) => p.mode(),
            Backend::Multi(p) => p.tenant(lane).mode(),
        }
    }

    /// Submit with the lane's default budget at the current clock time.
    pub fn submit(&self, tenant: usize, image: BitVec) -> Result<u64, Rejected> {
        let now = self.clock.now();
        self.submit_at(tenant, image, None, now)
    }

    /// Submit with an explicit end-to-end latency budget (the lane's
    /// batch closes once half of it is spent queueing).
    pub fn submit_with_budget(
        &self,
        tenant: usize,
        image: BitVec,
        budget: Duration,
    ) -> Result<u64, Rejected> {
        let now = self.clock.now();
        self.submit_at(tenant, image, Some(budget), now)
    }

    /// Admission stage with a caller-hoisted timestamp: bounds the lane's
    /// queue depth and tags the request.  On success the returned id is
    /// also the request's noise-stream index (rejections never consume
    /// an id, so accepted streams stay dense in admission order).
    pub fn submit_at(
        &self,
        tenant: usize,
        image: BitVec,
        budget: Option<Duration>,
        now: Timestamp,
    ) -> Result<u64, Rejected> {
        let lane = &self.lanes[tenant];
        // a pool past every recovery rung refuses typed rather than
        // serve silently wrong answers (the scrub ladder's last rung)
        let degraded = match &self.backend {
            Backend::Single(p) => p.degraded_mode(),
            Backend::Multi(p) => p.tenant(tenant).degraded_mode(),
        };
        let mut st = lane.state.lock().unwrap();
        if degraded == DegradedMode::Refusing {
            st.metrics.shed += 1;
            return Err(Rejected {
                tenant,
                reason: RejectReason::Degraded,
            });
        }
        let pending = st.batcher.pending();
        let limit = lane.admission.max_depth;
        if pending >= limit {
            st.metrics.shed += 1;
            return Err(Rejected {
                tenant,
                reason: RejectReason::QueueFull { pending, limit },
            });
        }
        st.metrics.admitted += 1;
        let id = match budget {
            Some(b) => st.batcher.push_with_budget(tenant, image, now, b),
            None => st.batcher.push_tagged(tenant, image, now),
        };
        drop(st);
        // wake parked workers: a new arrival may open a batch or move
        // the earliest deadline
        *self.arrivals.lock().unwrap() += 1;
        self.arrival_cv.notify_all();
        Ok(id)
    }

    /// One scheduler tick: drain every policy-ready batch, guaranteed
    /// lanes first.  Readiness is decided against a **single** clock
    /// reading taken at tick entry (one more read per executed batch
    /// stamps its completion) — the hoisted-clock contract a test pins
    /// via `Clock::reads`.
    pub fn poll(&self) -> Vec<Response> {
        self.tick(false)
    }

    /// Force-flush every lane regardless of policy (shutdown / epoch
    /// boundaries); each lane drains as one batch, like the facades'
    /// historical `poll(true)`.
    pub fn flush(&self) -> Vec<Response> {
        self.tick(true)
    }

    fn tick(&self, force: bool) -> Vec<Response> {
        self.ticks.fetch_add(1, Ordering::Relaxed);
        let now = self.clock.now(); // the tick's only readiness timestamp
        let mut out = Vec::new();
        for class in [QosClass::Guaranteed, QosClass::BestEffort] {
            for (t, lane) in self.lanes.iter().enumerate() {
                if lane.admission.class != class {
                    continue;
                }
                loop {
                    let batch = {
                        let mut st = lane.state.lock().unwrap();
                        if force {
                            st.batcher.drain_all()
                        } else if st.batcher.ready(now) {
                            st.batcher.drain_batch()
                        } else {
                            break;
                        }
                    };
                    if batch.is_empty() {
                        break;
                    }
                    self.execute(t, batch, &mut out);
                    if force {
                        break; // drain_all already took everything
                    }
                }
            }
        }
        self.run_maintenance();
        out
    }

    /// The maintenance hook: one turn of every registered task, at the
    /// end of each tick once every ready batch has drained.  A replan
    /// turn applies at most one migration step, so no serving gap ever
    /// waits on bulk work; no task reads the clock, preserving the
    /// hoisted-read contract.  `try_lock`: when workers tick
    /// concurrently, one runs maintenance and the rest skip.
    fn run_maintenance(&self) {
        let mut tasks = match self.maintenance.try_lock() {
            Ok(tasks) => tasks,
            Err(_) => return,
        };
        // contract, debug-asserted: a maintenance turn reads no clock —
        // the tick already hoisted its one readiness timestamp, and a
        // stray read here would break simulated-time replay
        let _clock_free = NoClockReads::begin();
        for task in tasks.iter_mut() {
            match task {
                MaintenanceTask::Replan { lane, controller } => {
                    let pool = match &self.backend {
                        Backend::Single(p) => p,
                        Backend::Multi(p) => p.tenant(*lane),
                    };
                    let saved_before = controller.retunes_saved;
                    let cost = controller.maintain(pool);
                    let saved = (controller.retunes_saved - saved_before).max(0) as u64;
                    if cost.steps > 0 || saved > 0 {
                        let mut st = self.lanes[*lane].state.lock().unwrap();
                        st.metrics.migration_steps += cost.steps;
                        st.metrics.migration_cycles += cost.programming_cycles();
                        st.metrics.migration_retunes_saved += saved;
                    }
                }
                MaintenanceTask::Recalibrate { period, ticks } => {
                    *ticks += 1;
                    if *ticks >= *period {
                        *ticks = 0;
                        self.recalibrate_pacing();
                    }
                }
                MaintenanceTask::Scrub { lane, controller } => {
                    let pool = match &self.backend {
                        Backend::Single(p) => p,
                        Backend::Multi(p) => p.tenant(*lane),
                    };
                    let delta = controller.maintain(pool);
                    let mut st = self.lanes[*lane].state.lock().unwrap();
                    st.metrics.add_scrub(&delta);
                    st.metrics.degraded = controller.degraded_mode();
                }
                MaintenanceTask::Fleet { supervisor } => {
                    let pool = match &self.backend {
                        Backend::Single(_) => panic!("fleet task on a single-tenant engine"),
                        Backend::Multi(p) => p,
                    };
                    for (lane, delta) in supervisor.maintain(pool).iter().enumerate() {
                        let mut st = self.lanes[lane].state.lock().unwrap();
                        st.metrics.add_scrub(delta);
                        st.metrics.degraded = supervisor.lane_scrub(lane).degraded_mode();
                    }
                }
            }
        }
    }

    /// Re-measure per-lane device pacing from the stats served since the
    /// last report and swap it into the `DevicePaced` model (lanes that
    /// served nothing keep their pacing; host-paced engines are a no-op).
    fn recalibrate_pacing(&self) {
        // clock-free like every maintenance turn (scopes nest, so this
        // also holds when called under `run_maintenance`'s own guard)
        let _clock_free = NoClockReads::begin();
        let mut service = self.service.lock().unwrap();
        let per_image = match &mut *service {
            ServiceModel::DevicePaced(per_image) => per_image,
            ServiceModel::HostPaced => return,
        };
        for lane in 0..self.lanes.len() {
            let stats = self.take_device_stats(lane);
            if let Some(per) = Self::pacing_from_stats(&stats) {
                per_image[lane] = per;
            }
        }
    }

    /// Per-image pacing from a served-stat delta, or `None` when the
    /// sample cannot produce a usable duration: nothing served, or a
    /// zero/non-finite per-image time (a drained-elsewhere or empty
    /// delta must leave the current pacing alone — installing a zero
    /// pacing would collapse the simulation to free batches, and a NaN
    /// would panic `Duration::from_secs_f64`).
    fn pacing_from_stats(stats: &RunStats) -> Option<Duration> {
        if stats.inferences == 0 {
            return None;
        }
        let per = stats.elapsed_s() / stats.inferences as f64;
        if !per.is_finite() || per <= 0.0 {
            return None;
        }
        Some(Duration::from_secs_f64(per))
    }

    /// Executor stage: classify one drained batch and record its lane
    /// metrics.  The lane lock is NOT held while classifying, so worker
    /// threads polling concurrently overlap their device batches.
    fn execute(&self, tenant: usize, batch: Vec<Request>, out: &mut Vec<Response>) {
        let n = batch.len();
        // FIFO drain of densely-id'd requests: the batch covers the
        // contiguous noise-stream range [base, base + n)
        let base = batch[0].id;
        let mut meta = Vec::with_capacity(n);
        let mut images = Vec::with_capacity(n);
        for req in batch {
            debug_assert_eq!(req.tenant, tenant, "lane holds one tenant");
            debug_assert_eq!(req.id, base + meta.len() as u64, "ids dense in batch");
            meta.push((req.id, req.enqueued));
            images.push(req.image);
        }
        let results = match &self.backend {
            Backend::Single(p) => p.classify_batch_at(&images, base),
            Backend::Multi(p) => p.classify_batch_at(tenant, &images, base),
        };
        let advance = match &*self.service.lock().unwrap() {
            ServiceModel::DevicePaced(per_image) => Some(per_image[tenant] * n as u32),
            ServiceModel::HostPaced => None,
        };
        if let Some(device_time) = advance {
            self.clock.advance(device_time);
        }
        let done = self.clock.now();
        let mut st = self.lanes[tenant].state.lock().unwrap();
        st.metrics.batches += 1;
        st.metrics.batch_sizes.push(n as f64);
        out.reserve(n);
        for ((id, enqueued), (votes, prediction)) in meta.into_iter().zip(results) {
            let latency = done.saturating_sub(enqueued);
            st.metrics.served += 1;
            st.metrics.latency_ms.push(latency.as_secs_f64() * 1e3);
            out.push(Response {
                id,
                tenant,
                prediction,
                votes,
                latency,
            });
        }
    }

    /// Requests queued in one lane.
    pub fn pending(&self, lane: usize) -> usize {
        self.lanes[lane].state.lock().unwrap().batcher.pending()
    }

    /// The end-to-end latency budget assigned to requests submitted to
    /// `lane` without an explicit one — the ingress-ring default for
    /// `Submission { budget: None, .. }` (see
    /// [`BatchPolicy::default_budget`]).
    pub fn default_budget(&self, lane: usize) -> Duration {
        self.lanes[lane]
            .state
            .lock()
            .unwrap()
            .batcher
            .policy()
            .default_budget()
    }

    /// Requests queued across all lanes.
    pub fn total_pending(&self) -> usize {
        (0..self.lanes.len()).map(|t| self.pending(t)).sum()
    }

    /// Scheduler ticks executed so far (polls + flushes).  The
    /// parked-worker test pins that an idle engine performs zero ticks
    /// between arrivals.
    pub fn ticks(&self) -> u64 {
        self.ticks.load(Ordering::Relaxed)
    }

    /// The earliest instant at which some lane's batch becomes ready
    /// (`None` when every lane is empty) — how long a parked worker may
    /// sleep without missing a deadline.
    pub fn next_deadline(&self) -> Option<Timestamp> {
        self.lanes
            .iter()
            .filter_map(|lane| lane.state.lock().unwrap().batcher.next_deadline())
            .min()
    }

    /// Park the calling worker until a new submission is admitted or
    /// `timeout` passes; returns whether an arrival woke it.  A parked
    /// worker performs no ticks and reads no clock — this condvar wait
    /// is what replaces spin-polling.  Arrivals admitted between the
    /// caller's last poll and this wait are not lost: the generation
    /// counter makes the wait return immediately.
    pub fn wait_for_arrival(&self, timeout: Duration) -> bool {
        let seen = self.arrivals.lock().unwrap();
        let start = *seen;
        let (guard, _) = self
            .arrival_cv
            .wait_timeout_while(seen, timeout, |generation| *generation == start)
            .unwrap();
        *guard != start
    }

    /// One tick when work is (or may be) due, otherwise park until an
    /// arrival or the earliest lane deadline (capped at `max_park`).
    /// Worker loops call this instead of spinning on [`Self::poll`]; an
    /// idle engine blocked here burns no CPU.  With a simulated clock
    /// the deadline wait degenerates to "park until an arrival" — the
    /// thread that advances virtual time is the one submitting.
    pub fn poll_or_park(&self, max_park: Duration) -> Vec<Response> {
        let wait = match self.next_deadline() {
            // idle: nothing can become ready until a submission lands
            None => max_park,
            Some(deadline) => {
                let remaining = deadline.saturating_sub(self.clock.now());
                if remaining.is_zero() {
                    return self.poll(); // a batch is already due
                }
                remaining.min(max_park)
            }
        };
        let woke = self.wait_for_arrival(wait);
        if !woke && self.total_pending() == 0 {
            return Vec::new(); // still idle: no tick, no clock read
        }
        self.poll()
    }

    /// Snapshot of one lane's metrics.
    pub fn lane_metrics(&self, lane: usize) -> ServerMetrics {
        self.lanes[lane].state.lock().unwrap().metrics.clone()
    }

    /// Clear one lane's latency/batch-size summaries (epoch boundaries:
    /// drop warmup samples; counters keep accumulating — they are the
    /// delta base for [`Self::take_device_stats`]).
    pub fn reset_latency_metrics(&self, lane: usize) {
        let mut st = self.lanes[lane].state.lock().unwrap();
        st.metrics.latency_ms = Default::default();
        st.metrics.batch_sizes = Default::default();
    }

    /// Drain one lane's device statistics accumulated since the previous
    /// call for that lane (delta-based: each served inference is
    /// attributed to exactly one report).
    pub fn take_device_stats(&self, lane: usize) -> RunStats {
        let mut st = self.lanes[lane].state.lock().unwrap();
        let delta = st.metrics.served - st.stats_reported;
        st.stats_reported = st.metrics.served;
        drop(st);
        match &self.backend {
            Backend::Single(p) => p.take_stats(delta),
            Backend::Multi(p) => p.take_stats(lane, delta),
        }
    }

    /// Measure each lane's steady-state device time per inference by
    /// running `warmup` images through the pool (doubles as the warmup
    /// epoch: construction programming and first funnel parks drain
    /// here), and return the [`ServiceModel::DevicePaced`] cost model.
    /// The calibration replays noise streams `[0, warmup)` — the same
    /// stateless streams the first admitted requests will use, so it
    /// perturbs nothing.
    pub fn calibrate_device_pacing(&self, images_per_lane: &[Vec<BitVec>]) -> ServiceModel {
        assert_eq!(images_per_lane.len(), self.lanes.len());
        let per_image = images_per_lane
            .iter()
            .enumerate()
            .map(|(t, imgs)| {
                assert!(!imgs.is_empty(), "lane {t}: calibration needs images");
                let stats = match &self.backend {
                    Backend::Single(p) => {
                        p.classify_batch_at(imgs, 0);
                        p.take_stats(imgs.len() as u64)
                    }
                    Backend::Multi(p) => {
                        p.classify_batch_at(t, imgs, 0);
                        p.take_stats(t, imgs.len() as u64)
                    }
                };
                Duration::from_secs_f64(stats.elapsed_s() / imgs.len() as f64)
            })
            .collect();
        ServiceModel::DevicePaced(per_image)
    }
}

impl Lane {
    fn new(policy: BatchPolicy) -> Self {
        Lane {
            admission: AdmissionPolicy::default(),
            state: Mutex::new(LaneState {
                batcher: Batcher::new(policy),
                metrics: ServerMetrics::default(),
                stats_reported: 0,
            }),
        }
    }
}

/// A submission travelling the bounded ingress ring.
#[derive(Clone, Debug)]
pub struct Submission {
    pub tenant: usize,
    pub image: BitVec,
    /// Explicit latency budget; `None` = the lane's default.
    pub budget: Option<Duration>,
}

/// Producer handle of the bounded MPSC ingress (cloneable across
/// producer threads).
#[derive(Clone)]
pub struct IngressTx {
    tx: SyncSender<Submission>,
    capacity: usize,
}

impl IngressTx {
    /// Non-blocking send: a full ring rejects with the typed
    /// [`RejectReason::IngressFull`] — open-loop producers shed here
    /// instead of queueing unboundedly.
    pub fn try_submit(&self, s: Submission) -> Result<(), Rejected> {
        let tenant = s.tenant;
        self.tx.try_send(s).map_err(|e| match e {
            TrySendError::Full(_) => Rejected {
                tenant,
                reason: RejectReason::IngressFull {
                    capacity: self.capacity,
                },
            },
            TrySendError::Disconnected(_) => Rejected {
                tenant,
                reason: RejectReason::ShuttingDown,
            },
        })
    }

    /// Blocking send (closed-loop producers); errors only at shutdown.
    pub fn submit_blocking(&self, s: Submission) -> Result<(), Rejected> {
        let tenant = s.tenant;
        self.tx.send(s).map_err(|_| Rejected {
            tenant,
            reason: RejectReason::ShuttingDown,
        })
    }
}

/// Bounded MPSC ingress seam (std `sync_channel`): producers on the
/// [`IngressTx`] side, the engine's dispatch loop on the `Receiver`.
pub fn ingress_channel(capacity: usize) -> (IngressTx, Receiver<Submission>) {
    let (tx, rx) = mpsc::sync_channel(capacity);
    (IngressTx { tx, capacity }, rx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::cam::NoiseMode;
    use crate::util::rng::Rng;

    fn images(n: usize, bits: usize) -> Vec<BitVec> {
        let mut rng = Rng::new(8, 8);
        (0..n)
            .map(|_| {
                let mut v = BitVec::zeros(bits);
                for i in 0..bits {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect()
    }

    fn opts() -> PipelineOptions {
        PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        }
    }

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn deadline_closes_a_batch_at_half_budget() {
        let model = tiny_model(64, 8, 3, 51);
        let engine = Engine::single(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 100,
                max_wait: Duration::from_secs(60),
            },
            crate::accel::DEFAULT_POOL_MACROS,
        )
        .with_clock(Clock::simulated());
        engine
            .submit_with_budget(0, images(1, 64).pop().unwrap(), ms(10))
            .unwrap();
        engine.clock().advance(ms(4));
        assert!(engine.poll().is_empty(), "budget less than half spent");
        engine.clock().advance(ms(1));
        let got = engine.poll();
        assert_eq!(got.len(), 1, "half the 10 ms budget spent in queue");
        assert_eq!(got[0].latency, ms(5));
    }

    #[test]
    fn poll_tick_uses_one_readiness_timestamp() {
        // the hoisted-clock satellite: an empty tick reads the clock
        // exactly once; a tick that executes k batches reads it 1 + k
        // times (one completion stamp per batch) — never once per queue
        // scan iteration or per request
        let model = tiny_model(64, 8, 3, 52);
        let engine = Engine::single(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO,
            },
            crate::accel::DEFAULT_POOL_MACROS,
        )
        .with_clock(Clock::simulated());
        let before = engine.clock().reads();
        assert!(engine.poll().is_empty());
        assert_eq!(engine.clock().reads() - before, 1, "empty tick");
        for img in images(3 * 8, 64) {
            engine.submit(0, img).unwrap();
        }
        let before = engine.clock().reads();
        let got = engine.poll();
        assert_eq!(got.len(), 24);
        assert_eq!(
            engine.clock().reads() - before,
            1 + 3,
            "one readiness read + one completion stamp per batch"
        );
    }

    #[test]
    fn maintenance_turns_read_no_clock_and_tick_reads_stay_pinned() {
        // hardening satellite: with replan + recalibration + scrub all
        // attached, a tick still reads the simulated clock exactly once
        // plus one completion stamp per executed batch — the
        // maintenance turn contributes zero reads.  Debug builds also
        // assert this from the inside: `run_maintenance` (and
        // `recalibrate_pacing` within it) runs under a `NoClockReads`
        // scope, so any future clock read added to a controller panics
        // here instead of silently skewing replay.
        let model = tiny_model(64, 8, 3, 54);
        let engine = Engine::single(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO,
            },
            crate::accel::DEFAULT_POOL_MACROS,
        )
        .with_clock(Clock::simulated())
        .with_service(ServiceModel::DevicePaced(vec![Duration::from_micros(50)]))
        .with_replan(
            0,
            crate::accel::DEFAULT_POOL_MACROS,
            ReplanConfig {
                period: 1,
                ..Default::default()
            },
        )
        .with_recalibration(1)
        .with_scrub(0, 977, ScrubConfig::default());

        // empty tick: one readiness read, the maintenance turn none
        let before = engine.clock().reads();
        assert!(engine.poll().is_empty());
        assert_eq!(
            engine.clock().reads() - before,
            1,
            "empty tick with maintenance attached"
        );

        // two batches: one readiness read + two completion stamps, and
        // the recalibration turn (which re-derives pacing from the
        // served stats) still reads nothing
        for img in images(2 * 8, 64) {
            engine.submit(0, img).unwrap();
        }
        let before = engine.clock().reads();
        let got = engine.poll();
        assert_eq!(got.len(), 16);
        assert_eq!(
            engine.clock().reads() - before,
            1 + 2,
            "maintenance-heavy tick reads readiness + per-batch stamps only"
        );
    }

    #[test]
    fn admission_rejects_typed_when_the_lane_is_full() {
        let model = tiny_model(64, 8, 3, 53);
        let engine = Engine::single(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 100,
                max_wait: Duration::from_secs(60),
            },
            crate::accel::DEFAULT_POOL_MACROS,
        )
        .with_clock(Clock::simulated())
        .with_admission(
            0,
            AdmissionPolicy {
                class: QosClass::BestEffort,
                max_depth: 2,
            },
        );
        let imgs = images(3, 64);
        assert_eq!(engine.submit(0, imgs[0].clone()), Ok(0));
        assert_eq!(engine.submit(0, imgs[1].clone()), Ok(1));
        let err = engine.submit(0, imgs[2].clone()).unwrap_err();
        assert_eq!(
            err,
            Rejected {
                tenant: 0,
                reason: RejectReason::QueueFull {
                    pending: 2,
                    limit: 2,
                },
            }
        );
        let m = engine.lane_metrics(0);
        assert_eq!((m.admitted, m.shed), (2, 1));
        assert!((m.shed_rate() - 1.0 / 3.0).abs() < 1e-12);
        // shedding frees no slot: still full until a poll drains the lane
        assert!(engine.submit(0, imgs[2].clone()).is_err());
        assert_eq!(engine.flush().len(), 2);
        // ids stay dense over the accepted stream: the post-drain accept
        // continues at 2 (rejections never consumed an id)
        assert_eq!(engine.submit(0, imgs[2].clone()), Ok(2));
    }

    #[test]
    fn guaranteed_lanes_drain_before_best_effort() {
        let a = tiny_model(64, 8, 3, 54);
        let b = tiny_model(64, 8, 3, 55);
        let engine = Engine::multi(
            &[&a, &b],
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO,
            },
            48,
            &[],
        )
        .with_clock(Clock::simulated())
        .with_admission(
            0,
            AdmissionPolicy {
                class: QosClass::BestEffort,
                max_depth: usize::MAX,
            },
        )
        .with_admission(
            1,
            AdmissionPolicy {
                class: QosClass::Guaranteed,
                max_depth: usize::MAX,
            },
        );
        let pacing = engine.calibrate_device_pacing(&[images(4, 64), images(4, 64)]);
        let engine = engine.with_service(pacing);
        // both lanes backlogged; lane 1 (guaranteed) must serve first and
        // its requests must not pay for lane 0's service time
        for img in images(8, 64) {
            engine.submit(0, img.clone()).unwrap();
            engine.submit(1, img).unwrap();
        }
        let got = engine.poll();
        assert_eq!(got.len(), 16);
        assert_eq!(got[0].tenant, 1, "guaranteed lane drains first");
        let first_best_effort = got.iter().position(|r| r.tenant == 0).unwrap();
        assert!(
            got[..first_best_effort].iter().all(|r| r.tenant == 1),
            "no interleaving before the guaranteed lane is dry"
        );
        let p99_g = engine.lane_metrics(1).p99_ms();
        let p99_be = engine.lane_metrics(0).p99_ms();
        assert!(
            p99_g < p99_be,
            "guaranteed p99 {p99_g} must undercut best-effort {p99_be}"
        );
    }

    #[test]
    fn device_paced_engine_is_a_deterministic_simulation() {
        let model = tiny_model(64, 8, 3, 56);
        let run = || {
            let engine = Engine::single(
                &model,
                opts(),
                BatchPolicy {
                    max_batch: 4,
                    max_wait: ms(2),
                },
                crate::accel::DEFAULT_POOL_MACROS,
            )
            .with_clock(Clock::simulated());
            let pacing = engine.calibrate_device_pacing(&[images(4, 64)]);
            let engine = engine.with_service(pacing);
            let mut latencies = Vec::new();
            for (i, img) in images(10, 64).into_iter().enumerate() {
                engine.clock().advance_to(ms(i as u64));
                engine.submit(0, img).unwrap();
                latencies.extend(engine.poll().into_iter().map(|r| r.latency));
            }
            latencies.extend(engine.flush().into_iter().map(|r| r.latency));
            (latencies, engine.lane_metrics(0).served)
        };
        let (l1, served1) = run();
        let (l2, served2) = run();
        assert_eq!(served1, 10);
        assert_eq!((l1, served1), (l2, served2), "replay diverged");
    }

    #[test]
    fn ingress_ring_sheds_typed_when_full() {
        let (tx, rx) = ingress_channel(2);
        let sub = |t| Submission {
            tenant: t,
            image: BitVec::ones(8),
            budget: None,
        };
        tx.try_submit(sub(0)).unwrap();
        tx.try_submit(sub(1)).unwrap();
        let err = tx.try_submit(sub(7)).unwrap_err();
        assert_eq!(
            err,
            Rejected {
                tenant: 7,
                reason: RejectReason::IngressFull { capacity: 2 },
            }
        );
        assert_eq!(rx.recv().unwrap().tenant, 0);
        // a slot freed: the ring admits again
        tx.try_submit(sub(3)).unwrap();
        drop(rx);
        let err = tx.try_submit(sub(4)).unwrap_err();
        assert_eq!(err.reason, RejectReason::ShuttingDown);
    }

    #[test]
    fn idle_engine_parks_without_ticking() {
        // the condvar satellite: a worker loop on poll_or_park performs
        // zero ticks while the engine is idle, then wakes on arrival
        let model = tiny_model(64, 8, 3, 57);
        let engine = Engine::single(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::ZERO,
            },
            crate::accel::DEFAULT_POOL_MACROS,
        )
        .with_clock(Clock::simulated());
        std::thread::scope(|s| {
            let eng = &engine;
            let worker = s.spawn(move || {
                let mut served = 0usize;
                while served < 4 {
                    served += eng.poll_or_park(Duration::from_millis(50)).len();
                }
                served
            });
            // the worker parks: no submissions, so no ticks and no
            // simulated-clock reads while we watch
            std::thread::sleep(Duration::from_millis(20));
            assert_eq!(engine.ticks(), 0, "idle worker must not tick");
            assert_eq!(engine.clock().reads(), 0, "idle worker reads no clock");
            for img in images(4, 64) {
                engine.submit(0, img).unwrap();
            }
            assert_eq!(worker.join().unwrap(), 4);
        });
        assert!(engine.ticks() >= 1, "arrivals woke the worker");
    }

    #[test]
    fn recalibration_tracks_a_device_time_step_within_one_period() {
        let model = tiny_model(64, 8, 3, 58);
        let imgs = images(4, 64);
        let engine = Engine::single(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::ZERO,
            },
            crate::accel::DEFAULT_POOL_MACROS,
        )
        .with_clock(Clock::simulated());
        let pacing = engine.calibrate_device_pacing(&[imgs.clone()]);
        let engine = engine.with_service(pacing).with_recalibration(1);
        // first served epoch: recalibration replaces the warmup estimate
        // (which still carried construction programming) with the
        // steady-state truth
        for img in &imgs {
            engine.submit(0, img.clone()).unwrap();
        }
        assert_eq!(engine.poll().len(), 4);
        let steady = match engine.service_model() {
            ServiceModel::DevicePaced(per) => per[0],
            ServiceModel::HostPaced => unreachable!(),
        };
        assert!(steady > Duration::ZERO);
        // inject a 2× device-time step (the model now claims the device
        // is twice as slow as it really is)...
        let engine = engine.with_service(ServiceModel::DevicePaced(vec![steady * 2]));
        // ...an idle tick must not track it (nothing served, no sample)
        assert!(engine.poll().is_empty());
        match engine.service_model() {
            ServiceModel::DevicePaced(per) => assert_eq!(per[0], steady * 2),
            ServiceModel::HostPaced => unreachable!(),
        }
        // one served epoch = one recalibration period: tracked back
        for img in &imgs {
            engine.submit(0, img.clone()).unwrap();
        }
        assert_eq!(engine.poll().len(), 4);
        match engine.service_model() {
            ServiceModel::DevicePaced(per) => {
                assert_eq!(per[0], steady, "2× step tracked within one period")
            }
            ServiceModel::HostPaced => unreachable!(),
        }
    }

    #[test]
    fn maintenance_replans_the_pool_between_batches() {
        // tentpole layer 4: the engine's maintenance hook drives the
        // re-planning controller, one migration step per tick, and the
        // lane metrics expose what the migration did.  Skewed traffic is
        // injected with banded sweeps on the shared pool; engine polls
        // provide the inter-batch gaps.
        let mut model = tiny_model(64, 8, 3, 59);
        model.schedule = vec![0, 0, 0, 0, 0, 0, 0, 0, 8, 16, 24, 32];
        let imgs = images(8, 64);
        let engine = Engine::single(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO,
            },
            4,
        )
        .with_clock(Clock::simulated())
        .with_replan(
            0,
            4,
            crate::accel::ReplanConfig {
                period: 2,
                decay: 0.0,
                ..Default::default()
            },
        );
        let before = engine.single_pool().plan().unwrap();
        let band = [8usize, 9, 10];
        let mut base = 0;
        for _ in 0..12 {
            engine.single_pool().classify_batch_positions(&imgs, base, &band);
            base += imgs.len() as u64;
            assert!(engine.poll().is_empty(), "maintenance must not serve");
        }
        let after = engine.single_pool().plan().unwrap();
        assert_ne!(after.pin_slot, before.pin_slot, "the pinned set moved");
        let m = engine.lane_metrics(0);
        assert!(m.migration_steps > 0, "steps surfaced in lane metrics");
        assert!(m.migration_retunes_saved > 0, "predicted saving surfaced");
        assert_eq!(m.migration_cycles, 0, "re-pins program no rows");
    }

    #[test]
    fn maintenance_scrubs_and_repairs_injected_faults() {
        // tentpole: the scrub maintenance task detects injected stuck
        // bits in the inter-batch gap, repairs them, and surfaces every
        // counter in the lane metrics
        use crate::cam::{FaultKind, FaultPlan, FaultSite};
        let model = tiny_model(64, 8, 3, 60);
        let engine = Engine::single(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO,
            },
            crate::accel::DEFAULT_POOL_MACROS,
        )
        .with_clock(Clock::simulated())
        .with_scrub(
            0,
            60,
            crate::accel::ScrubConfig {
                rows_per_turn: 1 << 20, // full pass per turn
                ..Default::default()
            },
        );
        // stuck bits with polarity opposite the stored golden weights,
        // so read-verify must flag them
        let golden = crate::bnn::mapping::program_row(&model.layers[0], 0, 0);
        let mut plan = FaultPlan::default();
        let site = FaultSite::Hidden {
            layer: 0,
            load: 0,
            replica: None,
        };
        for col in 0..2 {
            plan.push(
                0,
                site,
                FaultKind::StuckBit {
                    row: 0,
                    col,
                    bit: !golden.get(col),
                },
            );
        }
        engine.single_pool().inject_fault_plan(plan);
        // first served batch activates the faults; the trailing
        // maintenance turn scrubs and repairs them
        for img in images(8, 64) {
            engine.submit(0, img).unwrap();
        }
        assert_eq!(engine.poll().len(), 8);
        let m = engine.lane_metrics(0);
        assert!(m.scrubbed_rows > 0, "scrub progress surfaced");
        assert!(m.faults_detected > 0, "stuck row flagged");
        assert_eq!(m.faults_repaired, m.faults_detected, "repaired in place");
        assert_eq!(m.replica_rebuilds, 0, "no rebuild needed");
        assert_eq!(m.unrepairable, 0);
        assert_eq!(m.degraded, DegradedMode::Nominal, "repair keeps the lane nominal");
        // the repaired pool serves the next epoch bit-exactly: a
        // never-faulted twin classifying the same noise-stream range
        // must agree on every vote
        let imgs = images(8, 64);
        for img in &imgs {
            engine.submit(0, img.clone()).unwrap();
        }
        let mut got = engine.poll();
        assert_eq!(got.len(), 8);
        got.sort_by_key(|r| r.id);
        let twin = MacroPool::new(&model, opts());
        let want = twin.classify_batch_at(&imgs, 8);
        for (r, (votes, pred)) in got.iter().zip(&want) {
            assert_eq!(&r.prediction, pred);
            assert_eq!(&r.votes, votes);
        }
    }

    #[test]
    fn refusing_pool_rejects_submissions_typed() {
        // the degradation ladder's last rung: a Refusing pool sheds new
        // work with a typed reason while already-admitted work drains
        let model = tiny_model(64, 8, 3, 61);
        let engine = Engine::single(
            &model,
            opts(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_secs(60),
            },
            crate::accel::DEFAULT_POOL_MACROS,
        )
        .with_clock(Clock::simulated());
        engine.submit(0, images(1, 64).pop().unwrap()).unwrap();
        engine.single_pool().set_degraded_mode(DegradedMode::Refusing);
        let err = engine.submit(0, images(1, 64).pop().unwrap()).unwrap_err();
        assert_eq!(err.reason, RejectReason::Degraded);
        assert!(err.to_string().contains("refusing"));
        let m = engine.lane_metrics(0);
        assert_eq!((m.admitted, m.shed), (1, 1));
        // graceful: the admitted request still completes
        assert_eq!(engine.flush().len(), 1);
        // recovery (spares freed, replica swapped) reopens admission
        engine.single_pool().set_degraded_mode(DegradedMode::Nominal);
        assert!(engine.submit(0, images(1, 64).pop().unwrap()).is_ok());
    }

    #[test]
    fn default_budget_mirrors_the_lane_policy() {
        // satellite: the ingress dispatch resolves budget-less messages
        // through this accessor, so it must agree with the batcher's rule
        let model = tiny_model(64, 8, 3, 62);
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: ms(3),
        };
        let engine = Engine::single(&model, opts(), policy, crate::accel::DEFAULT_POOL_MACROS);
        assert_eq!(engine.default_budget(0), policy.default_budget());
        assert_eq!(engine.default_budget(0), ms(6));
    }

    #[test]
    fn pacing_guard_ignores_empty_and_zero_elapsed_samples() {
        // satellite: recalibration must never install a zero or NaN
        // pacing — an empty delta (nothing served, or stats drained
        // elsewhere) keeps the current model
        let idle = RunStats::default();
        assert_eq!(Engine::pacing_from_stats(&idle), None, "nothing served");
        let drained = RunStats {
            inferences: 8, // served, but cycle counters drained elsewhere
            ..Default::default()
        };
        assert_eq!(
            Engine::pacing_from_stats(&drained),
            None,
            "zero elapsed must not become zero pacing"
        );
        let sane = RunStats {
            inferences: 4,
            cycles: 4_000,
            ..Default::default()
        };
        assert!(Engine::pacing_from_stats(&sane).unwrap() > Duration::ZERO);
    }
}
