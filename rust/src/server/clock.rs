//! Time seam for the serving engine: wall time for deployments, a
//! deterministic simulated timeline for tests and open-loop benches.
//!
//! Every scheduling decision in the serving stack (batch deadlines,
//! latency stamps, service pacing) reads time through a [`Clock`] instead
//! of `std::time::Instant::now()`.  A [`Timestamp`] is a `Duration` since
//! the clock's epoch, so the same code path runs against either source:
//!
//! * [`Clock::wall`] — monotonic host time (an `Instant` epoch captured
//!   at construction).  The production default.
//! * [`Clock::simulated`] — a shared atomic nanosecond counter that only
//!   moves when [`Clock::advance`]/[`Clock::advance_to`] are called.
//!   Scheduling decisions become replayable: a test submits at t=0,
//!   advances to t=5 ms, and *knows* which batches close.  Simulated
//!   clocks also count [`Clock::now`] reads ([`Clock::reads`]) so tests
//!   can pin "one timestamp per scheduler tick" — the hoisted-clock-read
//!   contract of `server::Engine::poll`.
//!
//! Clones share the timeline: a wall clone copies the epoch (consistent
//! readings), a simulated clone shares the counter (advancing one
//! advances all) — the engine, its lanes, and an open-loop driver all
//! observe one notion of now.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Time since the owning [`Clock`]'s epoch.
pub type Timestamp = Duration;

/// Wall or simulated time source (module docs).
#[derive(Clone, Debug)]
pub struct Clock {
    inner: Inner,
}

#[derive(Clone, Debug)]
enum Inner {
    Wall(Instant),
    Simulated(Arc<SimState>),
}

#[derive(Debug, Default)]
struct SimState {
    /// Nanoseconds since the simulated epoch.
    nanos: AtomicU64,
    /// `now()` reads served (test instrumentation; module docs).
    reads: AtomicU64,
}

impl Clock {
    /// Monotonic host time; the epoch is the moment of construction.
    pub fn wall() -> Self {
        Clock {
            inner: Inner::Wall(Instant::now()),
        }
    }

    /// Deterministic virtual time starting at zero; advances only via
    /// [`Self::advance`]/[`Self::advance_to`].
    pub fn simulated() -> Self {
        Clock {
            inner: Inner::Simulated(Arc::new(SimState::default())),
        }
    }

    /// Current time since the epoch.
    ///
    /// Debug builds assert that no [`NoClockReads`] scope is active on
    /// the calling thread — the engine's maintenance turns (pacing
    /// recalibration, scrub, re-planning) are contractually clock-free,
    /// and a read sneaking into one would silently break the "one
    /// timestamp per tick" replay guarantee.
    pub fn now(&self) -> Timestamp {
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            NoClockReads::depth(),
            0,
            "Clock::now() inside a NoClockReads scope — maintenance turns \
             must reuse the tick's hoisted timestamp, not read the clock"
        );
        match &self.inner {
            Inner::Wall(epoch) => epoch.elapsed(),
            Inner::Simulated(s) => {
                s.reads.fetch_add(1, Ordering::Relaxed);
                Duration::from_nanos(s.nanos.load(Ordering::Relaxed))
            }
        }
    }

    /// Move a simulated clock forward by `d`.
    ///
    /// Panics on a wall clock — host time cannot be steered, and a
    /// service-pacing model wired to a wall clock is a configuration
    /// error the caller should hear about immediately.
    pub fn advance(&self, d: Duration) {
        match &self.inner {
            Inner::Wall(_) => panic!("Clock::advance on a wall clock"),
            Inner::Simulated(s) => {
                s.nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
            }
        }
    }

    /// Move a simulated clock forward *to* `t` — a no-op if the timeline
    /// is already past it (an open-loop driver replaying arrival times
    /// must never rewind a device that fell behind the offered load).
    /// Panics on a wall clock, like [`Self::advance`].
    pub fn advance_to(&self, t: Timestamp) {
        match &self.inner {
            Inner::Wall(_) => panic!("Clock::advance_to on a wall clock"),
            Inner::Simulated(s) => {
                let target = t.as_nanos() as u64;
                // lock-free max: only ever move forward
                let _ = s
                    .nanos
                    .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                        (target > cur).then_some(target)
                    });
            }
        }
    }

    /// Whether this clock is a simulated timeline.
    pub fn is_simulated(&self) -> bool {
        matches!(self.inner, Inner::Simulated(_))
    }

    /// `now()` reads served so far — simulated clocks only (0 on wall
    /// clocks, which stay instrumentation-free on the hot path).
    pub fn reads(&self) -> u64 {
        match &self.inner {
            Inner::Wall(_) => 0,
            Inner::Simulated(s) => s.reads.load(Ordering::Relaxed),
        }
    }
}

/// Debug-build guard declaring "this scope reads no clock".
///
/// The engine wraps each maintenance turn (`run_maintenance`, including
/// `recalibrate_pacing` and the scrub/replan controllers) in one of
/// these; any [`Clock::now`] on the same thread inside the scope trips
/// a `debug_assert`.  The check is a thread-local depth counter, so it
/// is exact — concurrent workers reading the clock on *other* threads
/// (which is fine) cannot trip it, unlike a global read-count delta,
/// which would be racy under concurrent submitters.  Release builds
/// compile it to nothing.
///
/// The type is deliberately `!Send` (it holds a raw-pointer marker):
/// a scope must begin and end on the thread whose reads it bans.
#[cfg(debug_assertions)]
pub struct NoClockReads {
    _not_send: std::marker::PhantomData<*const ()>,
}

#[cfg(debug_assertions)]
thread_local! {
    static NO_CLOCK_DEPTH: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
}

#[cfg(debug_assertions)]
impl NoClockReads {
    /// Enter a clock-free scope on this thread; the ban lifts when the
    /// returned guard drops.  Scopes nest.
    #[must_use = "the ban lasts only as long as the guard lives"]
    pub fn begin() -> Self {
        NO_CLOCK_DEPTH.with(|d| d.set(d.get() + 1));
        NoClockReads {
            _not_send: std::marker::PhantomData,
        }
    }

    fn depth() -> u32 {
        NO_CLOCK_DEPTH.with(|d| d.get())
    }
}

#[cfg(debug_assertions)]
impl Drop for NoClockReads {
    fn drop(&mut self) {
        NO_CLOCK_DEPTH.with(|d| d.set(d.get() - 1));
    }
}

/// Release-build stand-in: constructing it is free and bans nothing.
#[cfg(not(debug_assertions))]
pub struct NoClockReads;

#[cfg(not(debug_assertions))]
impl NoClockReads {
    pub fn begin() -> Self {
        NoClockReads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_time_only_moves_when_advanced() {
        let c = Clock::simulated();
        assert_eq!(c.now(), Duration::ZERO);
        assert_eq!(c.now(), Duration::ZERO, "no implicit progress");
        c.advance(Duration::from_millis(5));
        assert_eq!(c.now(), Duration::from_millis(5));
        c.advance(Duration::from_micros(250));
        assert_eq!(c.now(), Duration::from_micros(5250));
    }

    #[test]
    fn advance_to_never_rewinds() {
        let c = Clock::simulated();
        c.advance_to(Duration::from_millis(10));
        assert_eq!(c.now(), Duration::from_millis(10));
        c.advance_to(Duration::from_millis(3));
        assert_eq!(c.now(), Duration::from_millis(10), "rewound");
        c.advance_to(Duration::from_millis(12));
        assert_eq!(c.now(), Duration::from_millis(12));
    }

    #[test]
    fn clones_share_a_simulated_timeline() {
        let a = Clock::simulated();
        let b = a.clone();
        a.advance(Duration::from_secs(1));
        assert_eq!(b.now(), Duration::from_secs(1));
        b.advance(Duration::from_secs(1));
        assert_eq!(a.now(), Duration::from_secs(2));
    }

    #[test]
    fn simulated_counts_reads() {
        let c = Clock::simulated();
        assert_eq!(c.reads(), 0);
        let _ = c.now();
        let _ = c.now();
        assert_eq!(c.reads(), 2);
        // clones share the counter (one timeline, one read ledger)
        let _ = c.clone().now();
        assert_eq!(c.reads(), 3);
    }

    #[test]
    fn wall_clock_progresses_and_reports_zero_reads() {
        let c = Clock::wall();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        assert!(c.reads() == 0 && !c.is_simulated());
        // clones share the epoch: readings stay comparable
        let d = c.clone().now();
        assert!(d >= b);
    }

    #[test]
    #[should_panic(expected = "wall clock")]
    fn advancing_a_wall_clock_panics() {
        Clock::wall().advance(Duration::from_secs(1));
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "NoClockReads")]
    fn no_clock_reads_scope_trips_on_now() {
        let c = Clock::simulated();
        let _ban = NoClockReads::begin();
        let _ = c.now();
    }

    #[test]
    fn no_clock_reads_lifts_on_drop_and_nests() {
        let c = Clock::simulated();
        {
            let _outer = NoClockReads::begin();
            let _inner = NoClockReads::begin();
        }
        let _ = c.now();
        assert_eq!(c.reads(), 1);
    }

    #[cfg(debug_assertions)]
    #[test]
    fn no_clock_reads_is_thread_local() {
        // the ban must not leak to sibling threads: workers reading the
        // clock concurrently with a maintenance turn are legitimate
        let c = Clock::simulated();
        let _ban = NoClockReads::begin();
        let c2 = c.clone();
        std::thread::spawn(move || c2.now())
            .join()
            .expect("sibling thread reads freely");
        assert_eq!(c.reads(), 1);
    }
}
