//! # PiC-BNN — Processing-in-CAM Binary Neural Network Accelerator
//!
//! Full-system reproduction of "PiC-BNN: A 128-kbit 65 nm Processing-in-
//! CAM-Based End-to-End Binary Neural Network Accelerator" (CS.AR 2026).
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.
//!
//! The paper's silicon is replaced by a transistor-level-informed analog
//! simulator ([`analog`], [`cam`]); the accelerator coordination layer
//! ([`accel`], [`server`]) is the rust L3 of the three-layer stack; the
//! JAX/Pallas L2/L1 graphs are AOT-lowered to HLO text and executed from
//! rust via PJRT ([`runtime`]).

// Bit-index loops over packed vectors (`v.set(i, …)`) are the codebase
// idiom — the range-loop lint would rewrite them into less clear iterator
// chains.  `Json::to_string` mirrors serde_json's API shape on purpose,
// and the fork-join result plumbing carries one deep tuple type.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::inherent_to_string)]
#![allow(clippy::type_complexity)]

pub mod accel;
pub mod analog;
pub mod analysis;
pub mod baseline;
pub mod benchkit;
pub mod bnn;
pub mod cam;
pub mod data;
pub mod energy;
pub mod riscv;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod testkit;
pub mod util;

/// Crate version (for CLI banners).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Locate the artifacts directory: $PICBNN_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("PICBNN_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
