//! # PiC-BNN — Processing-in-CAM Binary Neural Network Accelerator
//!
//! Full-system reproduction of "PiC-BNN: A 128-kbit 65 nm Processing-in-
//! CAM-Based End-to-End Binary Neural Network Accelerator" (CS.AR 2026).
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.
//!
//! The paper's silicon is replaced by a transistor-level-informed analog
//! simulator ([`analog`], [`cam`]); the accelerator coordination layer
//! ([`accel`], [`server`]) is the rust L3 of the three-layer stack; the
//! JAX/Pallas L2/L1 graphs are AOT-lowered to HLO text and executed from
//! rust via PJRT ([`runtime`]).

pub mod accel;
pub mod analog;
pub mod baseline;
pub mod benchkit;
pub mod bnn;
pub mod cam;
pub mod data;
pub mod energy;
pub mod riscv;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod testkit;
pub mod util;

/// Crate version (for CLI banners).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Locate the artifacts directory: $PICBNN_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("PICBNN_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
