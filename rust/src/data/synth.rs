//! Rust-side synthetic workload generator.
//!
//! The *canonical* datasets (the ones the models were trained on) come from
//! `python/compile/data.py` via `artifacts/*_test.bin`; this module
//! generates structurally similar binary images for benches and property
//! tests that need workloads without trained weights — prototype-plus-noise
//! classes over packed ±1 vectors.

use crate::util::bitops::BitVec;
use crate::util::rng::Rng;

/// A synthetic prototype-noise dataset: `n_classes` random prototypes of
/// `n_features` bits; each sample flips each prototype bit with `noise_p`.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub n_features: usize,
    pub n_classes: usize,
    pub noise_p: f64,
    pub seed: u64,
}

impl SynthSpec {
    pub fn new(n_features: usize, n_classes: usize, noise_p: f64, seed: u64) -> Self {
        SynthSpec {
            n_features,
            n_classes,
            noise_p,
            seed,
        }
    }

    /// MNIST-shaped default (784 features, 10 classes).
    pub fn mnist_like(seed: u64) -> Self {
        SynthSpec::new(784, 10, 0.08, seed)
    }

    /// HG-shaped default (4096 features, 20 classes).
    pub fn hg_like(seed: u64) -> Self {
        SynthSpec::new(4096, 20, 0.04, seed)
    }
}

/// Generated dataset: prototypes + labelled noisy samples.
#[derive(Clone, Debug)]
pub struct SynthData {
    pub prototypes: Vec<BitVec>,
    pub images: Vec<BitVec>,
    pub labels: Vec<u8>,
    pub spec: SynthSpec,
}

impl SynthData {
    pub fn generate(spec: SynthSpec, n_samples: usize) -> SynthData {
        let mut rng = Rng::new(spec.seed, 0x5EED);
        let prototypes: Vec<BitVec> = (0..spec.n_classes)
            .map(|_| {
                let mut p = BitVec::zeros(spec.n_features);
                for i in 0..spec.n_features {
                    p.set(i, rng.chance(0.5));
                }
                p
            })
            .collect();
        let mut images = Vec::with_capacity(n_samples);
        let mut labels = Vec::with_capacity(n_samples);
        for _ in 0..n_samples {
            let c = rng.below(spec.n_classes as u64) as usize;
            let mut img = prototypes[c].clone();
            for i in 0..spec.n_features {
                if rng.chance(spec.noise_p) {
                    img.flip(i);
                }
            }
            images.push(img);
            labels.push(c as u8);
        }
        SynthData {
            prototypes,
            images,
            labels,
            spec,
        }
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = SynthData::generate(SynthSpec::new(128, 4, 0.05, 7), 50);
        let b = SynthData::generate(SynthSpec::new(128, 4, 0.05, 7), 50);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn noise_rate_near_p() {
        let d = SynthData::generate(SynthSpec::new(1024, 3, 0.1, 1), 200);
        let mut flips = 0u64;
        for (img, &lab) in d.images.iter().zip(&d.labels) {
            flips += img.hamming(&d.prototypes[lab as usize]) as u64;
        }
        let rate = flips as f64 / (1024.0 * 200.0);
        assert!((rate - 0.1).abs() < 0.01, "{rate}");
    }

    #[test]
    fn nearest_prototype_is_label() {
        // with low noise every sample is closest to its own prototype
        let d = SynthData::generate(SynthSpec::new(512, 8, 0.05, 3), 100);
        for (img, &lab) in d.images.iter().zip(&d.labels) {
            let dists: Vec<u32> = d.prototypes.iter().map(|p| p.hamming(img)).collect();
            let nearest = dists
                .iter()
                .enumerate()
                .min_by_key(|(_, &d)| d)
                .unwrap()
                .0;
            assert_eq!(nearest, lab as usize);
        }
    }
}
