//! Data front-end: artifact loaders for the canonical (python-exported)
//! test sets and model metadata, plus a rust-native synthetic workload
//! generator for benches/property tests.

pub mod idx;
pub mod loader;
pub mod synth;

pub use loader::{ModelMeta, TestSet};
pub use synth::{SynthData, SynthSpec};
