//! IDX-format reader (the standard MNIST container: big-endian magic,
//! dims, raw data).  The offline build uses synthetic data, but a
//! downstream user with the real `t10k-images-idx3-ubyte` files can point
//! the binarising front-end straight at them.
//!
//! Format: u32 magic 0x0000_08XX (0x08 = u8 data, XX = #dims), then one
//! big-endian u32 per dimension, then the payload in row-major order.

use std::io::Read;
use std::path::Path;

use crate::util::bitops::BitVec;

/// A parsed IDX tensor of u8 data.
#[derive(Clone, Debug)]
pub struct IdxTensor {
    pub dims: Vec<usize>,
    pub data: Vec<u8>,
}

impl IdxTensor {
    pub fn parse(buf: &[u8]) -> Result<IdxTensor, String> {
        if buf.len() < 4 {
            return Err("truncated IDX header".into());
        }
        if buf[0] != 0 || buf[1] != 0 {
            return Err("bad IDX magic (first two bytes must be zero)".into());
        }
        if buf[2] != 0x08 {
            return Err(format!("unsupported IDX dtype 0x{:02x} (want u8)", buf[2]));
        }
        let n_dims = buf[3] as usize;
        if n_dims == 0 || n_dims > 4 {
            return Err(format!("implausible IDX rank {n_dims}"));
        }
        let header = 4 + 4 * n_dims;
        if buf.len() < header {
            return Err("truncated IDX dims".into());
        }
        let mut dims = Vec::with_capacity(n_dims);
        for d in 0..n_dims {
            let o = 4 + 4 * d;
            dims.push(u32::from_be_bytes(buf[o..o + 4].try_into().unwrap()) as usize);
        }
        // `dims.iter().product()` wraps in release mode: a crafted header
        // like [2^31, 2^31, 4] multiplies to 2^64 ≡ 0, which defeats the
        // size check below (an empty payload "matches") and then blows up
        // `binarize_images`' `i*m..(i+1)*m` slicing.  Reject any header
        // whose element count is not exactly representable.
        let expect = dims
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| format!("IDX dims {dims:?} overflow the addressable size"))?;
        if buf.len() != header + expect {
            return Err(format!(
                "IDX payload size {} != expected {}",
                buf.len() - header,
                expect
            ));
        }
        Ok(IdxTensor {
            dims,
            data: buf[header..].to_vec(),
        })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<IdxTensor, String> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())
            .map_err(|e| format!("open {}: {e}", path.as_ref().display()))?
            .read_to_end(&mut buf)
            .map_err(|e| e.to_string())?;
        IdxTensor::parse(&buf)
    }

    /// Number of samples (first dimension).
    pub fn n(&self) -> usize {
        self.dims[0]
    }

    /// Elements per sample.
    pub fn sample_len(&self) -> usize {
        self.dims[1..].iter().product()
    }
}

/// Binarise IDX image data into the BNN's ±1 packed code: pixel > threshold
/// becomes +1 (the standard MNIST binarisation at 128).
pub fn binarize_images(images: &IdxTensor, threshold: u8) -> Vec<BitVec> {
    let m = images.sample_len();
    (0..images.n())
        .map(|i| {
            let mut v = BitVec::zeros(m);
            for (j, &px) in images.data[i * m..(i + 1) * m].iter().enumerate() {
                if px > threshold {
                    v.set(j, true);
                }
            }
            v
        })
        .collect()
}

/// Build a `TestSet` from a real MNIST pair (images + labels IDX files).
pub fn testset_from_idx(
    images_path: impl AsRef<Path>,
    labels_path: impl AsRef<Path>,
    threshold: u8,
) -> Result<super::loader::TestSet, String> {
    let images = IdxTensor::load(images_path)?;
    let labels = IdxTensor::load(labels_path)?;
    if labels.dims.len() != 1 || labels.n() != images.n() {
        return Err(format!(
            "label/image count mismatch: {} vs {}",
            labels.n(),
            images.n()
        ));
    }
    let n_classes = labels.data.iter().copied().max().unwrap_or(0) as usize + 1;
    Ok(super::loader::TestSet {
        images: binarize_images(&images, threshold),
        labels: labels.data.clone(),
        n_features: images.sample_len(),
        n_classes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_idx(dims: &[u32], data: &[u8]) -> Vec<u8> {
        let mut out = vec![0, 0, 0x08, dims.len() as u8];
        for &d in dims {
            out.extend_from_slice(&d.to_be_bytes());
        }
        out.extend_from_slice(data);
        out
    }

    #[test]
    fn parse_images_and_labels() {
        let img = make_idx(&[2, 3, 3], &[0; 18]);
        let t = IdxTensor::parse(&img).unwrap();
        assert_eq!(t.dims, vec![2, 3, 3]);
        assert_eq!(t.n(), 2);
        assert_eq!(t.sample_len(), 9);
        let lab = make_idx(&[2], &[7, 1]);
        let t = IdxTensor::parse(&lab).unwrap();
        assert_eq!(t.data, vec![7, 1]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(IdxTensor::parse(&[0, 0]).is_err());
        assert!(IdxTensor::parse(&[1, 0, 8, 1, 0, 0, 0, 0]).is_err());
        assert!(IdxTensor::parse(&make_idx(&[5], &[0; 3])).is_err()); // size lie
        let mut float_dtype = make_idx(&[1], &[0]);
        float_dtype[2] = 0x0d;
        assert!(IdxTensor::parse(&float_dtype).is_err());
    }

    #[test]
    fn rejects_overflowing_dims_instead_of_wrapping() {
        // regression: dims [2^31, 2^31, 4] multiply to 2^64, which wraps
        // to 0 in release mode — the payload-size check then *passes* on
        // an empty payload and binarize_images' row slicing panics (or
        // worse, silently reads the wrong rows).  A crafted header must
        // be rejected up front.
        let wrap_to_zero = make_idx(&[1 << 31, 1 << 31, 4], &[]);
        let err = IdxTensor::parse(&wrap_to_zero).unwrap_err();
        assert!(err.contains("overflow"), "{err}");
        // wrapping to a small nonzero count is just as dangerous: 2^64+2
        let wrap_to_two = make_idx(&[1 << 31, 1 << 31, 4, 2], &[0, 0]);
        // (product = 2^64 · 2 ≡ 0 — still the overflow path, payload lies)
        assert!(IdxTensor::parse(&wrap_to_two).is_err());
        // a dim of zero is fine — empty tensors multiply exactly
        let empty = make_idx(&[0, 28, 28], &[]);
        let t = IdxTensor::parse(&empty).unwrap();
        assert_eq!(t.n(), 0);
        assert!(binarize_images(&t, 128).is_empty());
    }

    #[test]
    fn binarize_threshold() {
        let img = IdxTensor::parse(&make_idx(&[1, 2, 2], &[0, 100, 200, 255])).unwrap();
        let bits = binarize_images(&img, 128);
        assert_eq!(bits.len(), 1);
        assert!(!bits[0].get(0));
        assert!(!bits[0].get(1));
        assert!(bits[0].get(2));
        assert!(bits[0].get(3));
    }

    #[test]
    fn testset_from_idx_roundtrip() {
        let dir = std::env::temp_dir().join("picbnn_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let img_path = dir.join("images");
        let lab_path = dir.join("labels");
        std::fs::write(&img_path, make_idx(&[3, 2, 2], &[200, 0, 0, 0, 0, 200, 0, 0, 0, 0, 200, 0]))
            .unwrap();
        std::fs::write(&lab_path, make_idx(&[3], &[0, 1, 2])).unwrap();
        let ts = testset_from_idx(&img_path, &lab_path, 128).unwrap();
        assert_eq!(ts.len(), 3);
        assert_eq!(ts.n_features, 4);
        assert_eq!(ts.n_classes, 3);
        assert!(ts.images[0].get(0));
        assert!(ts.images[1].get(1));
        std::fs::remove_dir_all(&dir).ok();
    }
}
