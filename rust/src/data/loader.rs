//! Loader for the `PICTEST1` packed test-set format written by
//! `python/compile/train.py::write_test_bin`, plus meta.json access.
//!
//! Layout (little-endian):
//! ```text
//! magic  8 B  "PICTEST1"
//! u32 × 3     n_samples, n_features, n_classes
//! u8 × n      labels
//! u64 × (n × ceil(n_features/64))  packed ±1 images (bit set = +1)
//! ```

use std::io::Read;
use std::path::Path;

use crate::util::bitops::{words_for, BitVec};
use crate::util::json::Json;

/// A binary test set (images as packed ±1 vectors).
#[derive(Clone, Debug)]
pub struct TestSet {
    pub images: Vec<BitVec>,
    pub labels: Vec<u8>,
    pub n_features: usize,
    pub n_classes: usize,
}

impl TestSet {
    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TestSet, String> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())
            .map_err(|e| format!("open {}: {e}", path.as_ref().display()))?
            .read_to_end(&mut buf)
            .map_err(|e| e.to_string())?;
        Self::from_bytes(&buf)
    }

    pub fn from_bytes(buf: &[u8]) -> Result<TestSet, String> {
        if buf.len() < 20 || &buf[..8] != b"PICTEST1" {
            return Err("bad magic (not a PICTEST1 file)".into());
        }
        let rd_u32 = |o: usize| u32::from_le_bytes(buf[o..o + 4].try_into().unwrap()) as usize;
        let n = rd_u32(8);
        let m = rd_u32(12);
        let n_classes = rd_u32(16);
        let words = words_for(m);
        let expect = 20 + n + n * words * 8;
        if buf.len() != expect {
            return Err(format!("size mismatch: {} vs expected {expect}", buf.len()));
        }
        let labels = buf[20..20 + n].to_vec();
        if labels.iter().any(|&l| l as usize >= n_classes) {
            return Err("label out of class range".into());
        }
        let mut images = Vec::with_capacity(n);
        let base = 20 + n;
        for i in 0..n {
            let mut w = Vec::with_capacity(words);
            for j in 0..words {
                let o = base + (i * words + j) * 8;
                w.push(u64::from_le_bytes(buf[o..o + 8].try_into().unwrap()));
            }
            images.push(BitVec::from_words(w, m));
        }
        Ok(TestSet {
            images,
            labels,
            n_features: m,
            n_classes,
        })
    }
}

/// Model metadata exported next to the weights (accuracies, dims, config).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub n_in: usize,
    pub n_hidden: usize,
    pub n_classes: usize,
    pub software_top1: f64,
    pub software_top2: f64,
    pub cam_nominal_top1: f64,
    pub paper_software_top1: f64,
    pub paper_cam_top1: f64,
    pub layer_configs: Vec<String>,
}

impl ModelMeta {
    pub fn load(path: impl AsRef<Path>) -> Result<ModelMeta, String> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("read {}: {e}", path.as_ref().display()))?;
        let j = Json::parse(&text)?;
        let num = |k: &str| -> Result<f64, String> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("meta missing numeric field '{k}'"))
        };
        Ok(ModelMeta {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            n_in: num("n_in")? as usize,
            n_hidden: num("n_hidden")? as usize,
            n_classes: num("n_classes")? as usize,
            software_top1: num("software_top1")?,
            software_top2: num("software_top2")?,
            cam_nominal_top1: num("cam_nominal_top1")?,
            paper_software_top1: num("paper_software_top1")?,
            paper_cam_top1: num("paper_cam_top1")?,
            layer_configs: j
                .get("layer_configs")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|v| v.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_bytes(n: usize, m: usize, n_cls: usize) -> Vec<u8> {
        let words = words_for(m);
        let mut out = Vec::new();
        out.extend_from_slice(b"PICTEST1");
        for v in [n as u32, m as u32, n_cls as u32] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for i in 0..n {
            out.push((i % n_cls) as u8);
        }
        for i in 0..n {
            for j in 0..words {
                out.extend_from_slice(&((i * 31 + j) as u64).to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn parse_wellformed() {
        let bytes = make_bytes(5, 130, 3);
        let ts = TestSet::from_bytes(&bytes).unwrap();
        assert_eq!(ts.len(), 5);
        assert_eq!(ts.n_features, 130);
        assert_eq!(ts.n_classes, 3);
        assert_eq!(ts.labels, vec![0, 1, 2, 0, 1]);
        assert_eq!(ts.images[0].len(), 130);
    }

    #[test]
    fn reject_bad_magic_and_size() {
        assert!(TestSet::from_bytes(b"WRONG!!!").is_err());
        let mut bytes = make_bytes(3, 64, 2);
        bytes.pop();
        assert!(TestSet::from_bytes(&bytes).is_err());
    }

    #[test]
    fn reject_label_out_of_range() {
        let mut bytes = make_bytes(3, 64, 2);
        bytes[20] = 9; // label 9 with n_classes = 2
        assert!(TestSet::from_bytes(&bytes).is_err());
    }

    #[test]
    fn meta_parses_real_shape() {
        let tmp = std::env::temp_dir().join("picbnn_meta_test.json");
        std::fs::write(
            &tmp,
            r#"{"name":"mnist","n_in":784,"n_hidden":128,"n_classes":10,
                "software_top1":0.96,"software_top2":0.99,
                "cam_nominal_top1":0.95,"paper_software_top1":0.952,
                "paper_cam_top1":0.952,"layer_configs":["1024x128","512x256"]}"#,
        )
        .unwrap();
        let meta = ModelMeta::load(&tmp).unwrap();
        assert_eq!(meta.name, "mnist");
        assert_eq!(meta.n_in, 784);
        assert_eq!(meta.layer_configs, vec!["1024x128", "512x256"]);
        std::fs::remove_file(&tmp).ok();
    }
}
