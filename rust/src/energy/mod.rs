//! Energy / power / area model (Table II regeneration).
//!
//! Converts the primitive event counts tallied by the simulator
//! (`sim::EventCounters`) into joules using 65 nm-calibrated per-event
//! energies (`analog::constants`), and combines them with the cycle/stall
//! clock into power, throughput, and efficiency figures.  Nothing here is
//! hard-coded to the paper's headline numbers — they emerge (or don't)
//! from the counted events; EXPERIMENTS.md records the comparison.

use crate::accel::RunStats;
use crate::analog::constants as k;
use crate::cam::CAPACITY_BITS;
use crate::sim::EventCounters;

/// Energy breakdown for a workload [J].
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    pub precharge: f64,
    pub searchlines: f64,
    pub mlsa: f64,
    pub writes: f64,
    pub retunes: f64,
    pub leakage: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.precharge + self.searchlines + self.mlsa + self.writes + self.retunes + self.leakage
    }
}

/// Full hardware report for a run (the Table II row set).
#[derive(Clone, Copy, Debug)]
pub struct HwReport {
    pub inferences: u64,
    pub elapsed_s: f64,
    pub cycles_per_inference: f64,
    pub energy: EnergyBreakdown,
    /// Average power over the run [W].
    pub power_w: f64,
    /// Throughput [inferences/s].
    pub inf_per_s: f64,
    /// Power efficiency [inferences/s/W].
    pub inf_per_s_per_w: f64,
    /// Binary-op throughput [OPS]: XNOR+accumulate pairs per second.
    pub ops_per_s: f64,
    /// Energy efficiency [OPS/W] (the paper's "TOPs/s" row is TOPS/W).
    pub ops_per_w: f64,
    /// CAM macro area [mm²].
    pub macro_area_mm2: f64,
    /// SoC area [mm²] (macro + RISC-V control plane).
    pub soc_area_mm2: f64,
}

/// Convert event counts to an energy breakdown for a run of `elapsed_s`
/// on `macros` resident macros.
///
/// `k::P_LEAKAGE` is the Table II *per-macro* standby figure, so leakage
/// scales with how many macros the run kept powered: a multi-macro
/// `MacroPool` (or multi-tenant `MultiPool`) leaks on every resident
/// macro for the whole run, not just one.  (The dynamic terms already
/// scale naturally — they follow the event counts, wherever the events
/// happened.)  `macros = 0` (an empty/default report) is treated as 1.
pub fn energy_of(events: &EventCounters, elapsed_s: f64, macros: usize) -> EnergyBreakdown {
    // Precharge energy scales with the *discharged* fraction; on average
    // roughly half the cells on a searched row mismatch, but we charge the
    // full precharge per search (conservative, matches CV² accounting).
    EnergyBreakdown {
        precharge: events.cells_precharged as f64 * k::E_PRECHARGE_PER_CELL,
        searchlines: events.sl_toggles as f64 * k::E_SL_PER_CELL,
        mlsa: events.mlsa_evals as f64 * k::E_MLSA_PER_ROW,
        writes: events.cells_written as f64 * k::E_WRITE_PER_CELL,
        retunes: events.retunes as f64 * k::E_RETUNE,
        leakage: k::P_LEAKAGE * elapsed_s * macros.max(1) as f64,
    }
}

/// Binary operations: each logical MAC (payload XNOR + its wired-OR
/// accumulation) counts as 2 ops — the convention BNN accelerator papers
/// use.  Pad/spare cells burn energy but do no useful work, so they are
/// excluded (the paper's 184 "TOPs/s" row divides model ops, not cell
/// events, by power).
pub fn ops_of(events: &EventCounters) -> f64 {
    events.useful_macs as f64 * 2.0
}

/// Build the full report from run statistics.  Leakage is charged per
/// resident macro (`RunStats::macros`); the area rows stay per-macro —
/// they are the paper-comparison silicon figures.
pub fn report(stats: &RunStats) -> HwReport {
    let elapsed = stats.elapsed_s();
    let energy = energy_of(&stats.events, elapsed, stats.macros);
    let power = if elapsed > 0.0 {
        energy.total() / elapsed
    } else {
        0.0
    };
    let ops = ops_of(&stats.events);
    let macro_area =
        CAPACITY_BITS as f64 * k::AREA_BITCELL_MM2 * k::BANK_PERIPHERY_FACTOR * 2.0;
    HwReport {
        inferences: stats.inferences,
        elapsed_s: elapsed,
        cycles_per_inference: stats.cycles_per_inference(),
        energy,
        power_w: power,
        inf_per_s: stats.inferences_per_s(),
        inf_per_s_per_w: if power > 0.0 {
            stats.inferences_per_s() / power
        } else {
            0.0
        },
        ops_per_s: if elapsed > 0.0 { ops / elapsed } else { 0.0 },
        ops_per_w: if energy.total() > 0.0 {
            ops / energy.total()
        } else {
            0.0
        },
        macro_area_mm2: macro_area,
        soc_area_mm2: macro_area + k::AREA_SOC_REST_MM2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_stats() -> RunStats {
        // one MNIST-ish inference: 1 hidden search (1024×128) + 33 output
        // searches (512×256) + programming amortised away
        let mut ev = EventCounters::default();
        ev.searches = 34;
        ev.cells_precharged = 1024 * 128 + 33 * 512 * 256;
        ev.sl_toggles = 1024 + 33 * 512;
        ev.mlsa_evals = 128 + 33 * 256;
        ev.useful_macs = 784 * 128 + 33 * 128 * 10;
        RunStats {
            inferences: 1,
            cycles: 34,
            stall_s: 0.0,
            events: ev,
            macros: 1,
            ..RunStats::default()
        }
    }

    #[test]
    fn energy_positive_and_dominated_by_precharge() {
        let s = fake_stats();
        let e = energy_of(&s.events, s.elapsed_s(), 1);
        assert!(e.total() > 0.0);
        assert!(e.precharge > e.mlsa);
        assert!(e.precharge > e.searchlines);
    }

    #[test]
    fn leakage_scales_with_the_resident_macro_count() {
        // regression: P_LEAKAGE is the Table II *per-macro* 55 µW figure,
        // but energy_of used to charge it once regardless of pool size —
        // a 39-macro HG pool understated leakage (and overstated
        // inf/s/W) by up to 39×
        let mut s = fake_stats();
        assert_eq!(s.macros, 1, "fake stats model one macro");
        let single = report(&s);
        s.macros = 39;
        let pooled = report(&s);
        let ratio = pooled.energy.leakage / single.energy.leakage;
        assert!((ratio - 39.0).abs() < 1e-9, "leakage ratio {ratio}");
        // everything dynamic is unchanged, so the efficiency penalty is
        // exactly the extra leakage
        assert_eq!(pooled.energy.precharge, single.energy.precharge);
        assert!(pooled.power_w > single.power_w);
        assert!(pooled.inf_per_s_per_w < single.inf_per_s_per_w);
        // a defaulted report (macros = 0) behaves like one macro
        let zero = report(&RunStats::default());
        assert!(zero.power_w >= 0.0);
    }

    #[test]
    fn report_throughput_near_paper_regime() {
        // 34 cycles/inference at 25 MHz ≈ 735 K inf/s: same order as the
        // paper's 560 K (their extra cycles come from I/O + amortised
        // programming, which the full pipeline bench measures).
        let r = report(&fake_stats());
        assert!(r.inf_per_s > 3e5 && r.inf_per_s < 1.2e6, "{}", r.inf_per_s);
        assert!(r.cycles_per_inference > 30.0);
    }

    #[test]
    fn power_in_milliwatt_regime() {
        // sustained inference should land within ~10× of the paper's 0.8 mW
        let r = report(&fake_stats());
        assert!(
            r.power_w > 5e-5 && r.power_w < 1e-2,
            "power {} W",
            r.power_w
        );
    }

    #[test]
    fn efficiency_units_consistent() {
        let r = report(&fake_stats());
        assert!((r.inf_per_s_per_w - r.inf_per_s / r.power_w).abs() / r.inf_per_s_per_w < 1e-9);
        assert!(r.ops_per_w > 0.0);
    }

    #[test]
    fn area_near_paper() {
        let r = report(&fake_stats());
        assert!(r.macro_area_mm2 > 0.6 && r.macro_area_mm2 < 1.2, "{}", r.macro_area_mm2);
        assert!(r.soc_area_mm2 > r.macro_area_mm2);
    }

    #[test]
    fn zero_run_is_safe() {
        let r = report(&RunStats::default());
        assert_eq!(r.inferences, 0);
        assert!(r.power_w >= 0.0);
    }
}
