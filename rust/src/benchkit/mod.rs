//! Criterion-style benchmark harness (criterion is unavailable offline;
//! DESIGN.md §1).
//!
//! Provides timed microbenchmarks with warmup + adaptive iteration scaling,
//! and table-shaped "experiment" output for regenerating the paper's tables
//! and figures as aligned text blocks that are easy to diff against
//! EXPERIMENTS.md.

use crate::util::stats::Summary;
use std::time::Instant;

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Result of one timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f`, scaling iteration count until a sample batch takes ≥ ~20 ms,
/// then collect `samples` batches and report per-iteration statistics.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // warmup + calibration
    let mut iters: u64 = 1;
    loop {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t.elapsed().as_secs_f64();
        if dt > 0.02 || iters >= 1 << 24 {
            break;
        }
        iters = (iters * 4).min(1 << 24);
    }
    let samples = 12;
    let mut per_iter = Summary::new();
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t.elapsed().as_secs_f64() * 1e9 / iters as f64);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: per_iter.mean(),
        stddev_ns: per_iter.stddev(),
        median_ns: per_iter.median(),
        min_ns: per_iter.min(),
    };
    println!(
        "bench {:<44} {:>12}/iter  (±{:>9}, median {:>10}, {} iters × {} samples)",
        r.name,
        fmt_ns(r.mean_ns),
        fmt_ns(r.stddev_ns),
        fmt_ns(r.median_ns),
        iters,
        samples
    );
    r
}

/// Aligned-text table builder for experiment output.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals (table cells).
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_time() {
        let mut acc = 0u64;
        let r = bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters >= 1);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "long-header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["10".into(), "20000".into(), "30".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-header"));
        let lines: Vec<&str> = s.lines().filter(|l| !l.is_empty()).collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1000.0,
            stddev_ns: 0.0,
            median_ns: 1000.0,
            min_ns: 1000.0,
        };
        assert!((r.throughput(1.0) - 1e6).abs() < 1e-6);
    }
}
