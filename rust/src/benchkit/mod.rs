//! Criterion-style benchmark harness (criterion is unavailable offline;
//! DESIGN.md §1).
//!
//! Provides timed microbenchmarks with warmup + adaptive iteration scaling,
//! and table-shaped "experiment" output for regenerating the paper's tables
//! and figures as aligned text blocks that are easy to diff against
//! EXPERIMENTS.md.
//!
//! Two extras feed the perf-optimisation loop:
//! * `PICBNN_BENCH_QUICK=1` ([`quick_mode`]) collapses every [`bench`] to
//!   a couple of single-iteration samples — CI *runs* the hot-path benches
//!   this way so kernel regressions that panic or mis-shape output fail
//!   the pipeline (timings in quick mode are indicative only).
//! * [`emit_json`] persists results (`BENCH_*.json` at the repo root via
//!   [`bench_artifact_path`]) so future PRs have a perf trajectory to
//!   compare against.

use crate::bnn::model::{MappedLayer, MappedModel};
use crate::util::bitops::{active_backend, BitMatrix, BitVec};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Random bit vector for synthetic workload images.
pub fn synth_bits(n: usize, rng: &mut Rng) -> BitVec {
    let mut v = BitVec::zeros(n);
    for i in 0..n {
        v.set(i, rng.chance(0.5));
    }
    v
}

/// Random single-segment mapped layer (mirrors the python mapper's
/// shape) — the synthetic-model building block the experiment benches
/// and serving demos share, so the acceptance fixtures cannot drift
/// between them.
pub fn synth_layer(rng: &mut Rng, n_out: usize, n_in: usize, width: usize) -> MappedLayer {
    let rows: Vec<BitVec> = (0..n_out).map(|_| synth_bits(n_in, rng)).collect();
    let pads = width - n_in;
    let q = vec![(0..n_out)
        .map(|_| rng.range_u64(0, pads as u64) as i32)
        .collect()];
    MappedLayer {
        weights: BitMatrix::from_rows(&rows),
        q,
        seg_bounds: vec![0, n_in],
        seg_width: width,
    }
}

/// Synthetic mapped model over `(n_out, n_in, width)` layer shapes with
/// the standard 33-threshold Algorithm-1 schedule.  Layers draw from one
/// `Rng::new(seed, stream)` in order, so a given (seed, stream, shapes)
/// triple is a stable fixture across benches and examples — e.g. the
/// HG-shaped acceptance model is `(seed, 0xBE9C, &[(384, 1500, 2048),
/// (6, 384, 512)])`.
pub fn synth_model(seed: u64, stream: u64, layers: &[(usize, usize, usize)]) -> MappedModel {
    let mut rng = Rng::new(seed, stream);
    let layers = layers
        .iter()
        .map(|&(n_out, n_in, width)| synth_layer(&mut rng, n_out, n_in, width))
        .collect();
    let m = MappedModel {
        layers,
        schedule: (0..=64).step_by(2).collect(),
    };
    for l in &m.layers {
        l.validate().expect("synthetic layer valid");
    }
    m
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Result of one timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }

    /// Persistable record; `items_per_iter` (if any) yields items/s.
    pub fn record(&self, items_per_iter: Option<f64>) -> BenchRecord {
        BenchRecord {
            name: self.name.clone(),
            ns_per_iter: self.mean_ns,
            throughput: items_per_iter.map(|n| self.throughput(n)),
            backend: active_backend().name(),
            quick: quick_mode(),
        }
    }
}

/// One persisted benchmark record (see [`emit_json`]).
#[derive(Clone, Debug)]
pub struct BenchRecord {
    pub name: String,
    pub ns_per_iter: f64,
    /// Items per second, when the bench has a natural item count.
    pub throughput: Option<f64>,
    /// The Hamming backend active when the record was taken
    /// (`util::bitops::active_backend`) — perf trajectories are only
    /// comparable within one backend, so the artifact carries it.
    pub backend: &'static str,
    /// True when the record came from a [`quick_mode`] smoke run:
    /// single-iteration samples, persisted for artifact continuity but
    /// never valid as a regression baseline ([`compare_baseline`] skips
    /// them).
    pub quick: bool,
}

impl BenchRecord {
    /// Record from an already-computed (time, rate) pair — for experiment
    /// benches that measure whole runs rather than [`bench`] iterations.
    pub fn new(name: &str, ns_per_iter: f64, throughput: Option<f64>) -> Self {
        BenchRecord {
            name: name.to_string(),
            ns_per_iter,
            throughput,
            backend: active_backend().name(),
            quick: quick_mode(),
        }
    }
}

/// True when `PICBNN_BENCH_QUICK` is set to anything but `0`/empty:
/// single-iteration smoke runs for CI (module docs).
pub fn quick_mode() -> bool {
    std::env::var("PICBNN_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

/// Repo-root path for a benchmark artifact: cargo runs benches with
/// `CARGO_MANIFEST_DIR` at the workspace root.
pub fn bench_artifact_path(file_name: &str) -> PathBuf {
    std::env::var_os("CARGO_MANIFEST_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("."))
        .join(file_name)
}

/// Write records as a JSON array of `{name, ns_per_iter, throughput}`
/// objects (parseable by `util::json`) — the perf trajectory future PRs
/// diff against.  Non-finite values (a zero-time quick-mode sample makes
/// a throughput infinite) are written as `null`, never as bare
/// `inf`/`NaN` tokens the reader would reject.
pub fn emit_json(path: impl AsRef<Path>, records: &[BenchRecord]) -> std::io::Result<()> {
    let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
    let arr = Json::Arr(
        records
            .iter()
            .map(|r| {
                obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("ns_per_iter", num(r.ns_per_iter)),
                    ("throughput", r.throughput.map(num).unwrap_or(Json::Null)),
                    ("backend", Json::Str(r.backend.to_string())),
                    ("quick", Json::Bool(r.quick)),
                ])
            })
            .collect(),
    );
    let path = path.as_ref();
    std::fs::write(path, arr.to_string() + "\n")?;
    println!("bench results -> {}", path.display());
    Ok(())
}

/// Gate fresh records against a previously committed baseline artifact
/// (the [`emit_json`] format): returns one message per regression — a
/// record named in `names` whose throughput fell more than `tolerance`
/// (a fraction of the baseline, e.g. `0.2` = 20%) below the baseline
/// entry of the same name.
///
/// Skipped rather than gated (first runs and incomparable history never
/// fail): a missing/unparsable baseline file; baseline entries that are
/// missing, have no finite throughput, were taken in [`quick_mode`]
/// (single-iteration smoke samples), or ran on a *different Hamming
/// backend* than the fresh record — throughput is only comparable
/// within one backend, and an old-format entry with no backend field is
/// treated as incomparable.  Call this *before* [`emit_json`]
/// overwrites the baseline with the fresh records.
pub fn compare_baseline(
    path: impl AsRef<Path>,
    records: &[BenchRecord],
    names: &[&str],
    tolerance: f64,
) -> Vec<String> {
    let Ok(text) = std::fs::read_to_string(path.as_ref()) else {
        return Vec::new();
    };
    let Ok(base) = Json::parse(&text) else {
        return Vec::new();
    };
    let Some(entries) = base.as_arr() else {
        return Vec::new();
    };
    let mut regressions = Vec::new();
    for &name in names {
        let Some(rec) = records.iter().find(|r| r.name == name) else {
            continue;
        };
        let Some(fresh) = rec.throughput.filter(|t| t.is_finite()) else {
            continue;
        };
        let Some(entry) = entries
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
        else {
            continue;
        };
        // quick-mode smoke samples and cross-backend baselines are not
        // comparable — skip, never mis-gate
        if entry.get("quick") == Some(&Json::Bool(true)) {
            continue;
        }
        if entry.get("backend").and_then(Json::as_str) != Some(rec.backend) {
            continue;
        }
        let Some(old) = entry
            .get("throughput")
            .and_then(Json::as_f64)
            .filter(|t| t.is_finite() && *t > 0.0)
        else {
            continue;
        };
        if fresh < old * (1.0 - tolerance) {
            regressions.push(format!(
                "{name}: {fresh:.3e} items/s is more than {:.0}% below the \
                 committed baseline's {old:.3e} (backend {})",
                tolerance * 100.0,
                rec.backend
            ));
        }
    }
    regressions
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f`, scaling iteration count until a sample batch takes ≥ ~20 ms,
/// then collect `samples` batches and report per-iteration statistics.
///
/// Under [`quick_mode`] the calibration loop is skipped: one warmup call
/// plus two single-iteration samples — enough for CI to catch panics and
/// shape regressions without paying for stable statistics.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    let (iters, samples) = if quick_mode() {
        f(); // warmup: first-call cache builds stay out of the samples
        (1u64, 2usize)
    } else {
        // warmup + calibration
        let mut iters: u64 = 1;
        loop {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t.elapsed().as_secs_f64();
            if dt > 0.02 || iters >= 1 << 24 {
                break;
            }
            iters = (iters * 4).min(1 << 24);
        }
        (iters, 12usize)
    };
    let mut per_iter = Summary::new();
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t.elapsed().as_secs_f64() * 1e9 / iters as f64);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: per_iter.mean(),
        stddev_ns: per_iter.stddev(),
        median_ns: per_iter.median(),
        min_ns: per_iter.min(),
    };
    println!(
        "bench {:<44} {:>12}/iter  (±{:>9}, median {:>10}, {} iters × {} samples)",
        r.name,
        fmt_ns(r.mean_ns),
        fmt_ns(r.stddev_ns),
        fmt_ns(r.median_ns),
        iters,
        samples
    );
    r
}

/// Aligned-text table builder for experiment output.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals (table cells).
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_time() {
        let mut acc = 0u64;
        let r = bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters >= 1);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "long-header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["10".into(), "20000".into(), "30".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-header"));
        let lines: Vec<&str> = s.lines().filter(|l| !l.is_empty()).collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1000.0,
            stddev_ns: 0.0,
            median_ns: 1000.0,
            min_ns: 1000.0,
        };
        assert!((r.throughput(1.0) - 1e6).abs() < 1e-6);
    }

    #[test]
    fn emit_json_roundtrips_through_the_json_reader() {
        let r = BenchResult {
            name: "kernel_x".into(),
            iters: 4,
            mean_ns: 250.5,
            stddev_ns: 1.0,
            median_ns: 250.0,
            min_ns: 249.0,
        };
        let records = vec![
            r.record(Some(128.0)),
            BenchRecord::new("no_throughput", 10.0, None),
        ];
        let path = std::env::temp_dir().join("picbnn_bench_emit_test.json");
        emit_json(&path, &records).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("kernel_x"));
        assert!(
            (arr[0].get("ns_per_iter").unwrap().as_f64().unwrap() - 250.5).abs() < 1e-9
        );
        let rate = arr[0].get("throughput").unwrap().as_f64().unwrap();
        assert!((rate - 128.0 / 250.5e-9).abs() / rate < 1e-12);
        assert_eq!(arr[1].get("throughput"), Some(&Json::Null));
        // every record carries the active backend name + quick flag
        let backend = crate::util::bitops::active_backend().name();
        for e in arr {
            assert_eq!(e.get("backend").unwrap().as_str(), Some(backend));
            assert_eq!(e.get("quick"), Some(&Json::Bool(quick_mode())));
        }
        let _ = std::fs::remove_file(&path);
    }

    /// Env-independent record (tests must behave the same under
    /// PICBNN_BENCH_QUICK, which `BenchRecord::new` would latch).
    fn full_record(name: &str, throughput: Option<f64>) -> BenchRecord {
        BenchRecord {
            name: name.to_string(),
            ns_per_iter: 10.0,
            throughput,
            backend: crate::util::bitops::active_backend().name(),
            quick: false,
        }
    }

    #[test]
    fn compare_baseline_flags_only_real_regressions() {
        let path = std::env::temp_dir().join("picbnn_bench_baseline_test.json");
        // no baseline on disk: nothing to compare against, no failures
        let _ = std::fs::remove_file(&path);
        let fresh = vec![
            full_record("kern_fast", Some(1000.0)),
            full_record("kern_slow", Some(100.0)),
            full_record("kern_quick_base", Some(100.0)),
            full_record("kern_other_backend", Some(100.0)),
            full_record("kern_new", Some(5.0)),
            full_record("no_rate", None),
        ];
        assert!(compare_baseline(&path, &fresh, &["kern_fast"], 0.2).is_empty());
        // commit a baseline, then regress one record beyond 20%; quick
        // and cross-backend baseline entries must be skipped even when
        // the fresh number is far below them
        let mut baseline = vec![
            full_record("kern_fast", Some(1050.0)), // within 20%
            full_record("kern_slow", Some(500.0)),  // 5x regression
            full_record("kern_quick_base", Some(500.0)),
            full_record("kern_other_backend", Some(500.0)),
            full_record("gone", Some(1.0)), // not re-measured
        ];
        baseline[2].quick = true; // smoke sample, not a valid baseline
        baseline[3].backend = "other"; // different Hamming backend
        emit_json(&path, &baseline).unwrap();
        let names = [
            "kern_fast",
            "kern_slow",
            "kern_quick_base",
            "kern_other_backend",
            "kern_new",
            "no_rate",
            "gone",
        ];
        let msgs = compare_baseline(&path, &fresh, &names, 0.2);
        assert_eq!(msgs.len(), 1, "{msgs:?}");
        assert!(msgs[0].starts_with("kern_slow:"), "{msgs:?}");
        // unparsable baseline: skipped, never a panic
        std::fs::write(&path, "not json").unwrap();
        assert!(compare_baseline(&path, &fresh, &["kern_slow"], 0.2).is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn quick_mode_reads_the_env_knob() {
        // avoid mutating the process environment (tests run in parallel):
        // only pin the default-off behaviour plus the artifact path shape
        if std::env::var_os("PICBNN_BENCH_QUICK").is_none() {
            assert!(!quick_mode());
        }
        let p = bench_artifact_path("BENCH_x.json");
        assert!(p.ends_with("BENCH_x.json"));
    }
}
