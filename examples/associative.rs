//! Associative-memory demo: the underlying approximate-search CAM [1] used
//! as an ADC-free nearest-neighbour engine — ternary masked search,
//! multi-match priority encoding, and best-match retrieval by binary-
//! searching the HD tolerance (the primitive Algorithm 1 specialises).
//!
//! Run: `cargo run --release --example associative`

use picbnn::accel::VoltageController;
use picbnn::analog::Pvt;
use picbnn::cam::ops::{masked_search, nearest_match, priority_encode};
use picbnn::cam::{CamArray, CamConfig};
use picbnn::data::{SynthData, SynthSpec};
use picbnn::util::bitops::BitVec;
use picbnn::util::rng::Rng;

fn main() {
    // a codebook of 8 random 512-bit prototypes
    let spec = SynthSpec::new(512, 8, 0.0, 42);
    let data = SynthData::generate(spec, 0);
    let mut cam = CamArray::analog(CamConfig::W512x256, 7);
    for (i, p) in data.prototypes.iter().enumerate() {
        cam.write_row(i, p);
    }
    println!("programmed {} prototypes into the 512×256 array", data.prototypes.len());

    // nearest-match retrieval for noisy probes
    let ctl = VoltageController::new(512, Pvt::nominal());
    let mut rng = Rng::new(9, 9);
    let mut total_searches = 0;
    let mut hits = 0;
    let probes = 50;
    for _ in 0..probes {
        let class = rng.below(8) as usize;
        let mut probe = data.prototypes[class].clone();
        for i in 0..512 {
            if rng.chance(0.06) {
                probe.flip(i);
            }
        }
        let got = nearest_match(&mut cam, &ctl, &probe, 256);
        total_searches += got.searches;
        if got.rows.contains(&class) {
            hits += 1;
        }
    }
    println!(
        "nearest-match: {hits}/{probes} probes retrieved their prototype, \
         avg {:.1} searches/probe (log₂ of the tolerance range — no ADC)",
        total_searches as f64 / probes as f64
    );

    // ternary masked search: wildcard the noisy half of a probe
    let probe_class = 3usize;
    let mut probe = data.prototypes[probe_class].clone();
    for i in 0..256 {
        if rng.chance(0.3) {
            probe.flip(i); // heavy corruption in the first half
        }
    }
    cam.set_voltages(picbnn::analog::Voltages::exact());
    let mut mask = BitVec::ones(512);
    for i in 0..256 {
        mask.set(i, false); // don't-care the corrupted half
    }
    let mut fires = Vec::new();
    masked_search(&mut cam, &probe, &mask, &mut fires);
    println!(
        "masked exact search over the clean half: priority encoder -> row {:?} (expected {probe_class})",
        priority_encode(&fires)
    );
}
