//! Quickstart: load the trained MNIST model, classify a handful of test
//! images on the simulated CAM, and print what the device saw.
//!
//! Run with: `cargo run --release --example quickstart` (after
//! `make artifacts`).

use picbnn::accel::{Pipeline, PipelineOptions};
use picbnn::bnn::model::MappedModel;
use picbnn::data::TestSet;

fn main() {
    let dir = picbnn::artifacts_dir();
    let model = MappedModel::load(dir.join("mnist_weights.bin"))
        .expect("run `make artifacts` first");
    let test = TestSet::load(dir.join("mnist_test.bin")).expect("test set");
    println!(
        "loaded binary MLP {} -> {} -> {} (schedule: {} output-layer executions)",
        model.n_in(),
        model.layers[0].n_out(),
        model.n_classes(),
        model.schedule.len()
    );

    // the full analog device: Monte-Carlo variation + per-evaluation noise
    let mut pipe = Pipeline::new(&model, PipelineOptions::default());

    let n = 8;
    let results = pipe.classify_batch(&test.images[..n]);
    for (i, (votes, pred)) in results.iter().enumerate() {
        let truth = test.labels[i];
        let mark = if *pred == truth as usize { "✓" } else { "✗" };
        println!("image {i}: true {truth}  predicted {pred} {mark}  votes {votes:?}");
    }

    let stats = pipe.take_stats(n as u64);
    println!(
        "\ndevice: {:.1} cycles/inference, {:.0} modelled inferences/s",
        stats.cycles_per_inference(),
        stats.inferences_per_s()
    );
}
