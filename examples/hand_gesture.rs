//! Hand-Gesture pipeline: the 4096-input model that exceeds the widest CAM
//! word (2048 cells) and therefore exercises split-row segmentation with
//! per-segment majority aggregation plus the weight-reload scheduler
//! (6 loads per batch; DESIGN.md §4).
//!
//! Run: `cargo run --release --example hand_gesture [-- --limit N]`

use picbnn::accel::{evaluate, Pipeline, PipelineOptions};
use picbnn::bnn::model::MappedModel;
use picbnn::cam::NoiseMode;
use picbnn::data::{ModelMeta, TestSet};
use picbnn::energy;
use picbnn::util::cli::Args;

fn main() {
    let args = Args::parse(&[]);
    let dir = picbnn::artifacts_dir();
    let model = MappedModel::load(dir.join("hg_weights.bin")).expect("run `make artifacts`");
    let test = TestSet::load(dir.join("hg_test.bin")).expect("test set");
    let meta = ModelMeta::load(dir.join("hg_meta.json")).expect("meta");
    let n = args.get_parse("limit", test.len()).min(test.len());

    let l1 = &model.layers[0];
    println!(
        "HG model: {} -> {} -> {}; input layer split into {} segments of {} cells",
        model.n_in(),
        l1.n_out(),
        model.n_classes(),
        l1.n_seg(),
        l1.seg_width
    );
    println!(
        "capacity: {} rows of 2048 needed vs 64 available -> {} weight loads per batch\n",
        l1.n_out() * l1.n_seg(),
        (l1.n_out() * l1.n_seg()).div_ceil(64)
    );

    for (label, noise) in [("nominal", NoiseMode::Nominal), ("analog", NoiseMode::Analog)] {
        let mut pipe = Pipeline::new(
            &model,
            PipelineOptions {
                noise,
                ..Default::default()
            },
        );
        let mut votes = Vec::with_capacity(n);
        for chunk in test.images[..n].chunks(256) {
            votes.extend(pipe.classify_batch(chunk).into_iter().map(|(v, _)| v));
        }
        let acc = evaluate(&votes, &test.labels[..n]);
        let stats = pipe.take_stats(n as u64);
        let r = energy::report(&stats);
        println!(
            "{label:<8} top1 {:.4}  top2 {:.4}  |  {:.1} cycles/inf, {:.0} inf/s, {:.3} mW",
            acc.top1,
            acc.top2,
            r.cycles_per_inference,
            r.inf_per_s,
            r.power_w * 1e3
        );
    }
    println!(
        "\npaper: CAM top1 0.935 vs software 0.99 (gap from binary-only input\nlayer); ours: CAM ~{:.3} vs software {:.3} — the same qualitative gap\nfrom split-row majority aggregation.",
        meta.cam_nominal_top1, meta.software_top1
    );
}
