//! SoC demo: the RV32I control CPU drives the CAM macro through its
//! memory-mapped register file, running the Algorithm-1 threshold sweep as
//! firmware — the paper's "RISC-V CPU that controls the SoC" ([41]),
//! end to end, for one real MNIST image.
//!
//! Run: `cargo run --release --example riscv_soc`

use picbnn::accel::VoltageController;
use picbnn::analog::Pvt;
use picbnn::bnn::infer::{digital_hidden, digital_output_hd, sweep_votes};
use picbnn::bnn::mapping::{program_row, segment_query};
use picbnn::bnn::model::MappedModel;
use picbnn::cam::{CamArray, CamConfig, NoiseMode};
use picbnn::data::TestSet;
use picbnn::riscv::cpu::MmioDevice;
use picbnn::riscv::mmio::{CamMmio, CMD_WRITE_ROW, DATA_BASE, REG_CMD, REG_ROW_ADDR};
use picbnn::riscv::{assemble, firmware};
use picbnn::util::bitops::BitVec;

fn poke_bits(dev: &mut CamMmio, base: u32, bits: &BitVec) {
    for w in 0..bits.len().div_ceil(32) {
        let mut word = 0u32;
        for b in 0..32 {
            let i = w * 32 + b;
            if i < bits.len() && bits.get(i) {
                word |= 1 << b;
            }
        }
        dev.write(base + 4 * w as u32, word);
    }
}

fn widen(bits: &BitVec, width: usize) -> BitVec {
    let mut out = BitVec::ones(width);
    for i in 0..bits.len() {
        if !bits.get(i) {
            out.set(i, false);
        }
    }
    out
}

fn main() {
    let dir = picbnn::artifacts_dir();
    let model = MappedModel::load(dir.join("mnist_weights.bin")).expect("run `make artifacts`");
    let test = TestSet::load(dir.join("mnist_test.bin")).expect("test set");
    let out_layer = model.layers.last().unwrap();
    let image = &test.images[0];
    let truth = test.labels[0];

    let fw = assemble(firmware::SWEEP_ASM).unwrap();
    println!("firmware: {} bytes of RV32I ({} instructions)", fw.len(), fw.len() / 4);

    // hidden layer on the host (the firmware demo covers the output sweep —
    // the part the paper repeats 33×)
    let hidden = digital_hidden(&model.layers[0], image);

    // SoC: CAM in the 512×256 config behind the register file
    let cfg = CamConfig::W512x256;
    let mut dev = CamMmio::new(CamArray::new(cfg, Pvt::nominal(), NoiseMode::Nominal, 0));
    for j in 0..out_layer.n_out() {
        let row = widen(&program_row(out_layer, 0, j), cfg.width());
        poke_bits(&mut dev, DATA_BASE, &row);
        dev.write(REG_ROW_ADDR, j as u32);
        dev.write(REG_CMD, CMD_WRITE_ROW);
    }
    println!("programmed {} class rows via MMIO", out_layer.n_out());

    // calibrate the Algorithm-1 schedule and hand it to the firmware
    let ctl = VoltageController::new(cfg.width(), Pvt::nominal());
    let targets: Vec<u32> = model.schedule.iter().map(|&t| t as u32).collect();
    let points = ctl.calibrate_schedule(&targets);
    let query = widen(&segment_query(out_layer, 0, &hidden), cfg.width());

    let (votes, instret) =
        firmware::run_sweep(&mut dev, &points, out_layer.n_out(), &query).expect("firmware");
    println!("firmware executed {instret} instructions for the 33-threshold sweep");
    println!("votes: {votes:?}");
    let pred = votes
        .iter()
        .enumerate()
        .max_by_key(|&(i, &v)| (v, usize::MAX - i))
        .unwrap()
        .0;
    println!("prediction {pred} (truth {truth})");

    // cross-check against the digital reference
    let hd = digital_output_hd(out_layer, &hidden);
    let want = sweep_votes(&hd, &model.schedule);
    assert_eq!(votes, want, "firmware votes must match the digital reference");
    println!("firmware output matches the digital reference bit-for-bit ✓");
}
