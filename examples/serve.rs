//! Batched inference service demo: producer threads fire requests at the
//! dynamic batcher in front of the CAM pipeline; reports latency
//! percentiles and throughput for several batching policies — the
//! batching/latency dial of paper §V-B as a deployment would see it.
//! Closes with the staged engine under a bursty open-loop workload on
//! virtual time: QoS admission shedding best-effort traffic with typed
//! rejections while the guaranteed lane keeps its latency.
//!
//! Run: `cargo run --release --example serve [-- --requests N]`

use std::time::Duration;

use picbnn::accel::{BatchPolicy, MacroPool, PipelineOptions};
use picbnn::benchkit::{synth_bits, synth_model, Table};
use picbnn::bnn::model::MappedModel;
use picbnn::data::TestSet;
use picbnn::server::{
    serve_workload, AdmissionPolicy, ArrivalProcess, Clock, Engine, MultiServer, QosClass,
    RejectReason, ServiceModel, Server, Workload,
};
use picbnn::util::bitops::BitVec;
use picbnn::util::cli::Args;
use picbnn::util::rng::Rng;
use picbnn::util::Timer;

/// Format a latency percentile, showing a placeholder until a request has
/// been served (`ServerMetrics::p50_ms` documents the NaN sentinel —
/// printing it raw would render "NaN" in the report).
fn fmt_ms(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.2}")
    } else {
        "-".into()
    }
}

/// HG-shaped synthetic tenant (1500 -> 384 -> 6; 39 macros full) for the
/// multi-tenant demo — a second model shape served from the same budget
/// (the same fixture the multi_tenant bench measures).
fn hg_shaped_tenant(seed: u64) -> MappedModel {
    synth_model(seed, 0xBE9C, &[(384, 1500, 2048), (6, 384, 512)])
}

fn main() {
    let args = Args::parse(&[]);
    let dir = picbnn::artifacts_dir();
    let model = MappedModel::load(dir.join("mnist_weights.bin")).expect("run `make artifacts`");
    let test = TestSet::load(dir.join("mnist_test.bin")).expect("test set");
    let requests = args.get_parse("requests", 4000usize);
    let images: Vec<_> = (0..requests)
        .map(|i| test.images[i % test.len()].clone())
        .collect();

    // the server fronts a resident MacroPool: weights stay programmed and
    // (budget allowing) every output threshold keeps pre-tuned rails
    // across the whole run; smaller budgets share output macros between
    // thresholds instead of dropping to the reload scheduler
    let opts = PipelineOptions::default();
    let required = MacroPool::macros_required(&model, &opts);
    match MacroPool::plan_for(&model, &opts, picbnn::accel::DEFAULT_POOL_MACROS) {
        Some(plan) => println!(
            "backing pool: {required} macros for full residency; default budget plans {}",
            plan.describe()
        ),
        None => println!("backing pool: hidden loads exceed the budget -> reload mode"),
    }

    let mut table = Table::new(
        "batching policy vs latency/throughput (4 producer threads)",
        &["max batch", "served", "batches", "mean batch", "p50 ms", "p99 ms", "host req/s"],
    );
    for max_batch in [1usize, 16, 64, 256] {
        let t = Timer::start();
        let (responses, metrics) = serve_workload(
            &model,
            opts,
            BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(1),
            },
            &images,
            4,
            Duration::ZERO,
        );
        table.row(vec![
            max_batch.to_string(),
            responses.len().to_string(),
            metrics.batches.to_string(),
            format!("{:.1}", metrics.mean_batch()),
            fmt_ms(metrics.p50_ms()),
            fmt_ms(metrics.p99_ms()),
            format!("{:.0}", responses.len() as f64 / t.elapsed_s()),
        ]);
    }
    table.print();
    println!("\nlarger batches amortise the 33 voltage retunes + weight loads per");
    println!("batch (higher throughput) at the cost of queueing latency.");

    // --- degraded macro budgets: the placement planner's latency cost ---
    // a model needing `required` macros still serves resident-ish at a
    // fraction of that budget, trading pinned thresholds for tracked
    // retunes; only budgets below the hidden loads reload
    let policy = BatchPolicy {
        max_batch: 64,
        max_wait: Duration::from_millis(1),
    };
    let mut table = Table::new(
        &format!("macro budget vs steady-state device cost (max batch 64, {} reqs)", requests),
        &["budget", "plan", "program cyc", "retunes", "p50 ms", "p99 ms"],
    );
    for budget in [required, required.div_ceil(2), required / 4] {
        let mut server = Server::with_capacity(&model, opts, policy, budget);
        let plan = server
            .pool()
            .plan()
            .map(|p| p.describe())
            .unwrap_or_else(|| "reload".into());
        // warmup epoch: construction programming + first shared parks
        for img in &images[..images.len().min(256)] {
            server.submit(img.clone());
        }
        server.poll(true);
        server.take_device_stats();
        // drop the warmup epoch's latencies so the table reports
        // steady-state percentiles (served/batches keep counting — they
        // are the delta base for take_device_stats)
        server.reset_latency_metrics();
        // steady state
        for img in &images {
            server.submit(img.clone());
            let _ = server.poll(false);
        }
        server.poll(true);
        let stats = server.take_device_stats();
        let m = server.metrics();
        table.row(vec![
            budget.to_string(),
            plan,
            stats.programming_cycles().to_string(),
            stats.events.retunes.to_string(),
            fmt_ms(m.p50_ms()),
            fmt_ms(m.p99_ms()),
        ]);
    }
    table.print();
    println!("\nhidden loads keep dedicated macros while the budget allows (zero");
    println!("steady-state programming); shrinking budgets un-pin output thresholds");
    println!("one by one, then cold-spill the smallest hidden loads to the funnel.");

    // --- multi-tenant serving: MNIST + an HG-shaped tenant, one budget ---
    let hg = hg_shaped_tenant(11);
    let tenants = [&model, &hg];
    let tenant_names = ["mnist", "hg-shaped"];
    let budget = MacroPool::macros_required(&model, &opts)
        + MacroPool::macros_required(&hg, &opts);
    let mut hg_rng = Rng::new(21, 4);
    let hg_images: Vec<BitVec> = (0..images.len().min(512))
        .map(|_| synth_bits(hg.n_in(), &mut hg_rng))
        .collect();
    let mut multi = MultiServer::new(&tenants, opts, policy, budget);
    println!("\nmulti-tenant pool over {budget} macros:");
    if let Some(tp) = multi.pool().plan() {
        println!("  {}", tp.describe());
    }
    // warmup epoch, then a steady interleaved epoch per tenant
    for img in hg_images.iter() {
        multi.submit(1, img.clone());
    }
    for img in images.iter().take(hg_images.len()) {
        multi.submit(0, img.clone());
    }
    multi.poll(true);
    multi.take_device_stats(0);
    multi.take_device_stats(1);
    for (a, b) in images.iter().take(hg_images.len()).zip(&hg_images) {
        multi.submit(0, a.clone());
        multi.submit(1, b.clone());
        let _ = multi.poll(false);
    }
    multi.poll(true);
    let mut table = Table::new(
        "one server, two tenants (steady state)",
        &["tenant", "plan", "served", "program cyc", "retunes", "p50 ms", "p99 ms"],
    );
    for t in 0..multi.n_tenants() {
        let stats = multi.take_device_stats(t);
        let plan = multi
            .pool()
            .tenant(t)
            .plan()
            .map(|p| p.describe())
            .unwrap_or_else(|| "reload".into());
        let m = multi.metrics(t);
        table.row(vec![
            tenant_names[t].into(),
            plan,
            m.served.to_string(),
            stats.programming_cycles().to_string(),
            stats.events.retunes.to_string(),
            fmt_ms(m.p50_ms()),
            fmt_ms(m.p99_ms()),
        ]);
    }
    table.print();
    println!("\ntwo model shapes share one macro budget: per-tenant plans pin every");
    println!("weight load once, and steady-state batches of either tenant pay");
    println!("searches + I/O only — zero programming, isolation bit-exact.");

    // --- bursty open-loop serving: QoS admission on the staged engine ---
    // the same two tenants behind one engine on a simulated clock, with
    // the device paced by its own measured per-image service time: mnist
    // rides the guaranteed class (unbounded lane) while the hg tenant is
    // best-effort behind a bounded queue.  Bursts push offered load past
    // device capacity, so the admission stage sheds best-effort requests
    // with typed rejections while the guaranteed lane keeps its latency.
    let engine = Engine::multi(&tenants, opts, policy, budget, &[])
        .with_clock(Clock::simulated())
        .with_admission(
            0,
            AdmissionPolicy {
                class: QosClass::Guaranteed,
                max_depth: usize::MAX,
            },
        )
        .with_admission(
            1,
            AdmissionPolicy {
                class: QosClass::BestEffort,
                max_depth: 2 * policy.max_batch,
            },
        );
    let warmup: [Vec<BitVec>; 2] = [
        images.iter().take(32).cloned().collect(),
        hg_images.iter().take(32).cloned().collect(),
    ];
    let pacing = engine.calibrate_device_pacing(&warmup);
    let ServiceModel::DevicePaced(ref per_image) = pacing else {
        unreachable!("calibration returns DevicePaced");
    };
    let capacity = 1.0 / per_image[0].max(per_image[1]).as_secs_f64();
    let engine = engine.with_service(pacing.clone());
    engine.reset_latency_metrics(0);
    engine.reset_latency_metrics(1);

    // ~2400 arrivals: 25% duty bursts at 2x capacity over a 0.4x floor
    let wl = Workload::generate(
        &ArrivalProcess::Bursty {
            base: capacity * 0.4,
            burst: capacity * 2.0,
            period: Duration::from_secs_f64(750.0 / capacity),
            duty: 0.25,
        },
        Duration::from_secs_f64(3000.0 / capacity),
        100_000,
        &[0.3, 0.7],
        0x5EED,
    );
    let clock = engine.clock();
    let mut rejected = 0usize;
    let mut i = 0;
    while i < wl.arrivals.len() {
        if wl.arrivals[i].at > clock.now() {
            clock.advance_to(wl.arrivals[i].at);
        }
        let now = clock.now();
        while i < wl.arrivals.len() && wl.arrivals[i].at <= now {
            let a = &wl.arrivals[i];
            let img = if a.tenant == 0 {
                images[(a.user % images.len() as u64) as usize].clone()
            } else {
                hg_images[(a.user % hg_images.len() as u64) as usize].clone()
            };
            if let Err(r) = engine.submit_at(a.tenant, img, None, now) {
                assert!(matches!(r.reason, RejectReason::QueueFull { .. }));
                rejected += 1;
            }
            i += 1;
        }
        engine.poll();
    }
    engine.flush();

    let mut table = Table::new(
        "bursty open-loop workload, one engine, two QoS classes (virtual time)",
        &["tenant", "class", "offered", "served", "shed", "shed %", "p50 ms", "p99 ms"],
    );
    for (t, class) in [(0usize, "guaranteed"), (1, "best-effort")] {
        let m = engine.lane_metrics(t);
        table.row(vec![
            tenant_names[t].into(),
            class.into(),
            (m.admitted + m.shed).to_string(),
            m.served.to_string(),
            m.shed.to_string(),
            format!("{:.1}", m.shed_rate() * 100.0),
            fmt_ms(m.p50_ms()),
            fmt_ms(m.p99_ms()),
        ]);
    }
    table.print();
    println!("\nburst peaks offer 2x the device's capacity: the bounded best-effort");
    println!("lane absorbs the overload ({rejected} typed QueueFull rejections) while");
    println!("the guaranteed lane's percentiles stay at the batching floor.");
}
