//! Batched inference service demo: producer threads fire requests at the
//! dynamic batcher in front of the CAM pipeline; reports latency
//! percentiles and throughput for several batching policies — the
//! batching/latency dial of paper §V-B as a deployment would see it.
//!
//! Run: `cargo run --release --example serve [-- --requests N]`

use std::time::Duration;

use picbnn::accel::{BatchPolicy, MacroPool, PipelineOptions};
use picbnn::benchkit::Table;
use picbnn::bnn::model::MappedModel;
use picbnn::data::TestSet;
use picbnn::server::serve_workload;
use picbnn::util::cli::Args;
use picbnn::util::Timer;

fn main() {
    let args = Args::parse(&[]);
    let dir = picbnn::artifacts_dir();
    let model = MappedModel::load(dir.join("mnist_weights.bin")).expect("run `make artifacts`");
    let test = TestSet::load(dir.join("mnist_test.bin")).expect("test set");
    let requests = args.get_parse("requests", 4000usize);
    let images: Vec<_> = (0..requests)
        .map(|i| test.images[i % test.len()].clone())
        .collect();

    // the server fronts a resident MacroPool: weights stay programmed and
    // every output threshold keeps pre-tuned rails across the whole run
    let opts = PipelineOptions::default();
    let required = MacroPool::macros_required(&model, &opts);
    println!(
        "backing pool: {} macros required, budget {} -> {} mode",
        required,
        picbnn::accel::DEFAULT_POOL_MACROS,
        if required <= picbnn::accel::DEFAULT_POOL_MACROS {
            "resident"
        } else {
            "reload"
        }
    );

    let mut table = Table::new(
        "batching policy vs latency/throughput (4 producer threads)",
        &["max batch", "served", "batches", "mean batch", "p50 ms", "p99 ms", "host req/s"],
    );
    for max_batch in [1usize, 16, 64, 256] {
        let t = Timer::start();
        let (responses, metrics) = serve_workload(
            &model,
            opts,
            BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(1),
            },
            &images,
            4,
            Duration::ZERO,
        );
        table.row(vec![
            max_batch.to_string(),
            responses.len().to_string(),
            metrics.batches.to_string(),
            format!("{:.1}", metrics.mean_batch()),
            format!("{:.2}", metrics.p50_ms()),
            format!("{:.2}", metrics.p99_ms()),
            format!("{:.0}", responses.len() as f64 / t.elapsed_s()),
        ]);
    }
    table.print();
    println!("\nlarger batches amortise the 33 voltage retunes + weight loads per");
    println!("batch (higher throughput) at the cost of queueing latency.");
}
