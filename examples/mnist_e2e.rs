//! **End-to-end driver** (DESIGN.md §6): the full PiC-BNN system on the
//! MNIST-like workload.
//!
//! 1. Loads the trained + CAM-mapped binary MLP and the canonical test set
//!    from artifacts (produced once by `make artifacts`).
//! 2. Runs Algorithm 1 over the entire test set on the analog CAM
//!    simulator (batched: voltage retunes amortised across images).
//! 3. Cross-checks a sample against the PJRT execution backend (the
//!    AOT-lowered JAX/Pallas graph) and the digital software baseline.
//! 4. Reports the paper's headline metrics: accuracy, throughput, power,
//!    energy efficiency.  Recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example mnist_e2e [-- --limit N]`

use picbnn::accel::{evaluate, MacroPool, Pipeline, PipelineOptions};
use picbnn::baseline::digital_predict;
use picbnn::bnn::model::MappedModel;
use picbnn::cam::NoiseMode;
use picbnn::data::{ModelMeta, TestSet};
use picbnn::energy;
use picbnn::runtime::InferEngine;
use picbnn::util::cli::Args;
use picbnn::util::Timer;

fn main() {
    let args = Args::parse(&[]);
    let dir = picbnn::artifacts_dir();
    let model =
        MappedModel::load(dir.join("mnist_weights.bin")).expect("run `make artifacts` first");
    let test = TestSet::load(dir.join("mnist_test.bin")).expect("test set");
    let meta = ModelMeta::load(dir.join("mnist_meta.json")).expect("meta");
    let n = args.get_parse("limit", test.len()).min(test.len());

    println!("== PiC-BNN end-to-end: MNIST-like, {n} images ==\n");

    // --- 1. software baseline (digital full-precision-output BNN) ---
    let t = Timer::start();
    let sw_correct = test.images[..n]
        .iter()
        .zip(&test.labels[..n])
        .filter(|(x, &y)| digital_predict(&model, x) == y as usize)
        .count();
    let sw_acc = sw_correct as f64 / n as f64;
    println!(
        "software baseline     top1 {:.4}   (paper: {:.3})   [{:.2}s]",
        sw_acc,
        meta.paper_software_top1,
        t.elapsed_s()
    );

    // --- 2. the device: analog CAM pool, Algorithm 1, batched ---
    // the resident MacroPool programs every layer segment and pre-tunes
    // every output threshold once, then serves batches with zero
    // reprogramming / zero retunes (falls back to the reload scheduler if
    // the model exceeded the pool capacity)
    let t = Timer::start();
    let pool = MacroPool::new(&model, PipelineOptions::default());
    println!(
        "device pool: {:?} mode, {} simulated macros",
        pool.mode(),
        pool.n_macros()
    );
    let mut votes = Vec::with_capacity(n);
    for chunk in test.images[..n].chunks(256) {
        votes.extend(pool.classify_batch(chunk).into_iter().map(|(v, _)| v));
    }
    let acc = evaluate(&votes, &test.labels[..n]);
    let stats = pool.take_stats(n as u64);
    println!(
        "PiC-BNN (analog sim)  top1 {:.4}   top2 {:.4}   (paper: {:.3})   [{:.2}s]",
        acc.top1,
        acc.top2,
        meta.paper_cam_top1,
        t.elapsed_s()
    );
    println!(
        "pool epoch: {} programming cycles, {} retune events (both one-off; steady-state batches pay zero)",
        stats.programming_cycles(),
        stats.events.retunes
    );

    // --- 3. cross-check vs the PJRT (AOT JAX/Pallas) backend ---
    let mut nominal = Pipeline::new(
        &model,
        PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        },
    );
    match InferEngine::load("mnist", &model) {
        Ok(engine) => {
            let k = 64.min(n);
            let pjrt = engine.classify_batch(&test.images[..k]).expect("pjrt run");
            let cam = nominal.classify_batch(&test.images[..k]);
            let agree = pjrt == cam;
            println!(
                "PJRT backend ({})  agrees with nominal CAM on {k}/{k} images: {}",
                engine.platform(),
                if agree { "YES (bit-exact)" } else { "NO" }
            );
            assert!(agree, "execution backends diverged");
        }
        Err(e) => println!("PJRT backend unavailable ({e}); skipped cross-check"),
    }

    // --- 4. hardware report (Table II) ---
    let r = energy::report(&stats);
    println!("\n== hardware report (vs paper Table II) ==");
    println!("throughput      {:>10.0} inf/s     (paper 560000)", r.inf_per_s);
    println!("power           {:>10.3} mW        (paper 0.8)", r.power_w * 1e3);
    println!(
        "efficiency      {:>10.0} M inf/s/W (paper 703)",
        r.inf_per_s_per_w / 1e6
    );
    println!(
        "efficiency      {:>10.0} TOPS/W    (paper '184 TOPs/s')",
        r.ops_per_w / 1e12
    );
    println!("cycles/inf      {:>10.1}           (paper ~44.6 implied)", r.cycles_per_inference);
    println!("macro area      {:>10.2} mm²       (paper 0.87)", r.macro_area_mm2);
    println!("SoC area        {:>10.2} mm²       (paper 2.38)", r.soc_area_mm2);
    let e = r.energy;
    println!(
        "\nenergy breakdown: precharge {:.1}% | SL {:.1}% | MLSA {:.1}% | writes {:.1}% | retune {:.1}% | leakage {:.1}%",
        100.0 * e.precharge / e.total(),
        100.0 * e.searchlines / e.total(),
        100.0 * e.mlsa / e.total(),
        100.0 * e.writes / e.total(),
        100.0 * e.retunes / e.total(),
        100.0 * e.leakage / e.total()
    );
}
