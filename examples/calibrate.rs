//! Regenerate the paper's Table I: run the voltage-calibration procedure
//! against the analog model and print the (V_ref, V_eval, V_st) triples
//! realising each HD tolerance target, then behaviourally verify each
//! point on a simulated array.
//!
//! The absolute millivolts differ from the silicon's (our closed-form
//! constants are effective, not extracted from that die — DESIGN.md §1);
//! the *structure* — three knobs jointly covering tolerance 0..36+ with
//! exact boundary behaviour — is the reproduced result.

use picbnn::accel::VoltageController;
use picbnn::analog::{Pvt, Voltages};
use picbnn::benchkit::Table;
use picbnn::cam::{CamArray, CamConfig};
use picbnn::util::bitops::BitVec;

fn main() {
    let ctl = VoltageController::new(256, Pvt::nominal());
    let mut table = Table::new(
        "Table I — (V_ref, V_eval, V_st) -> HD tolerance (256-cell rows)",
        &["HD tol", "V_ref (mV)", "V_eval (mV)", "V_st (mV)", "achieved", "verified"],
    );
    for target in (0..=36).step_by(4) {
        let p = ctl
            .calibrate(target, 0.5)
            .or_else(|| ctl.calibrate(target, 2.0))
            .expect("calibration target unreachable");
        // behavioural verification on an actual simulated array
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let stored = BitVec::ones(512);
        cam.write_row(0, &stored);
        cam.set_voltages(Voltages::new(
            p.voltages.vref,
            p.voltages.veval,
            p.voltages.vst,
        ));
        let mut ok = true;
        for m in 0..=(target + 6).min(256) {
            let mut q = stored.clone();
            for i in 0..m as usize {
                q.set(i, false);
            }
            // array is 512 wide; searching 256-cell-calibrated points on a
            // 256-cell payload: scale the probe to the calibrated width by
            // using the model directly
            let fires = ctl.model.fires_nominal(
                m,
                &p.voltages,
                &picbnn::analog::RowVariation::nominal(),
            );
            if fires != (m <= target) {
                ok = false;
            }
        }
        table.row(vec![
            target.to_string(),
            format!("{:.0}", p.voltages.vref * 1e3),
            format!("{:.0}", p.voltages.veval * 1e3),
            format!("{:.0}", p.voltages.vst * 1e3),
            format!("{:.2}", p.achieved_tol),
            if ok { "✓".into() } else { "✗".into() },
        ]);
    }
    table.print();
    println!("\npaper's Table I covers the same targets ({{0,4,...,36}}) with");
    println!("silicon-specific voltages; see EXPERIMENTS.md §T1 for the comparison.");
}
